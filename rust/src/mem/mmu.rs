//! SV39 address translation with hardware A/D update and a per-hart TLB.
//!
//! Matches the paper's target (Table III: "SV39 paged virtual memory").
//! The page walker issues real memory reads through the cache hierarchy so
//! PTW traffic shows up in the timing model, like Rocket's PTW does.

use super::{Access, MemSys};
use crate::rv64::Trap;

pub const PAGE_SIZE: u64 = 4096;
pub const PAGE_SHIFT: u64 = 12;

// PTE flag bits.
pub const PTE_V: u64 = 1 << 0;
pub const PTE_R: u64 = 1 << 1;
pub const PTE_W: u64 = 1 << 2;
pub const PTE_X: u64 = 1 << 3;
pub const PTE_U: u64 = 1 << 4;
pub const PTE_G: u64 = 1 << 5;
pub const PTE_A: u64 = 1 << 6;
pub const PTE_D: u64 = 1 << 7;

/// satp fields.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Satp(pub u64);

impl Satp {
    pub fn mode(&self) -> u64 {
        self.0 >> 60
    }
    pub fn asid(&self) -> u64 {
        (self.0 >> 44) & 0xffff
    }
    pub fn ppn(&self) -> u64 {
        self.0 & ((1 << 44) - 1)
    }
    pub fn make(mode: u64, asid: u64, ppn: u64) -> Satp {
        Satp((mode << 60) | (asid << 44) | ppn)
    }
    pub fn bare(&self) -> bool {
        self.mode() != 8
    }
}

fn fault(acc: Access, va: u64) -> Trap {
    match acc {
        Access::Fetch => Trap::InstPageFault(va),
        Access::Load => Trap::LoadPageFault(va),
        Access::Store => Trap::StorePageFault(va),
    }
}

/// Translate `va` for `hart`. Returns (paddr, extra cycles). M-mode and
/// bare satp pass through untranslated.
pub fn translate(
    ms: &mut MemSys,
    hart: usize,
    satp: Satp,
    user_mode: bool,
    va: u64,
    acc: Access,
) -> Result<(u64, u64), Trap> {
    if !user_mode || satp.bare() {
        // M-mode (controller-injected code) runs on physical addresses.
        return Ok((va, 0));
    }
    // SV39 requires bits 63..39 to equal bit 38.
    let sext = (va as i64) << 25 >> 25;
    if sext as u64 != va {
        return Err(fault(acc, va));
    }
    let vpn = va >> PAGE_SHIFT;
    if let Some((ppn, flags)) = ms.tlbs[hart].lookup(vpn) {
        check_perm(flags as u64, acc, va)?;
        return Ok(((ppn << PAGE_SHIFT) | (va & (PAGE_SIZE - 1)), 0));
    }
    ms.evt[hart].tlb_miss += 1;
    let (leaf_pte, leaf_level, pte_addr, mut cycles) = walk(ms, hart, satp, va, acc)?;
    // Superpage alignment check.
    let ppn_field = leaf_pte >> 10;
    for lvl in 0..leaf_level {
        if (ppn_field >> (9 * lvl)) & 0x1ff != 0 {
            return Err(fault(acc, va));
        }
    }
    check_perm(leaf_pte, acc, va)?;
    // Hardware A/D update (Rocket-style).
    let mut new_pte = leaf_pte | PTE_A;
    if acc == Access::Store {
        new_pte |= PTE_D;
    }
    if new_pte != leaf_pte {
        ms.phys.write_u64(pte_addr, new_pte);
        cycles += 1;
    }
    // Compose physical address (honouring superpage offset bits).
    let off_bits = PAGE_SHIFT + 9 * leaf_level as u64;
    let pa = ((ppn_field << PAGE_SHIFT) & !((1u64 << off_bits) - 1)) | (va & ((1u64 << off_bits) - 1));
    // Only 4K leaves are cached in the TLB (the runtime maps 4K pages).
    if leaf_level == 0 {
        ms.tlbs[hart].insert(vpn, pa >> PAGE_SHIFT, (new_pte & 0xff) as u8);
    }
    Ok((pa, cycles))
}

fn check_perm(pte: u64, acc: Access, va: u64) -> Result<(), Trap> {
    // User-mode access requires U; R/W/X per access type. (S-mode is not
    // used by FASE targets — the host runtime *is* the kernel.)
    if pte & PTE_U == 0 {
        return Err(fault(acc, va));
    }
    let ok = match acc {
        Access::Fetch => pte & PTE_X != 0,
        Access::Load => pte & PTE_R != 0,
        Access::Store => pte & PTE_W != 0,
    };
    if ok {
        Ok(())
    } else {
        Err(fault(acc, va))
    }
}

/// 3-level SV39 walk. Returns (leaf pte, level, pte paddr, cycles).
fn walk(
    ms: &mut MemSys,
    hart: usize,
    satp: Satp,
    va: u64,
    acc: Access,
) -> Result<(u64, usize, u64, u64), Trap> {
    let mut table_ppn = satp.ppn();
    let mut cycles = 0u64;
    for level in (0..3usize).rev() {
        let vpn_i = (va >> (PAGE_SHIFT + 9 * level as u64)) & 0x1ff;
        let pte_addr = (table_ppn << PAGE_SHIFT) + vpn_i * 8;
        let pte = ms.phys.read_u64(pte_addr).ok_or_else(|| fault(acc, va))?;
        ms.evt[hart].ptw_accesses += 1;
        // PTW reads go through the shared L2 (Rocket's PTW port).
        cycles += ms.lat.ptw_per_level;
        if !ms.l2.access(pte_addr & !(super::LINE - 1), false) {
            cycles += ms.lat.dram;
        }
        if pte & PTE_V == 0 || (pte & PTE_R == 0 && pte & PTE_W != 0) {
            return Err(fault(acc, va));
        }
        if pte & (PTE_R | PTE_X) != 0 {
            return Ok((pte, level, pte_addr, cycles));
        }
        table_ppn = pte >> 10;
    }
    Err(fault(acc, va))
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::rv64::Trap;

    const BASE: u64 = 0x8000_0000;

    /// Build a 3-level table mapping one 4K page va -> pa with `flags`.
    fn setup(ms: &mut MemSys, root: u64, va: u64, pa: u64, flags: u64) {
        let l2 = root + 0x1000;
        let l1 = root + 0x2000;
        let vpn2 = (va >> 30) & 0x1ff;
        let vpn1 = (va >> 21) & 0x1ff;
        let vpn0 = (va >> 12) & 0x1ff;
        ms.phys.write_u64(root + vpn2 * 8, ((l2 >> 12) << 10) | PTE_V);
        ms.phys.write_u64(l2 + vpn1 * 8, ((l1 >> 12) << 10) | PTE_V);
        ms.phys.write_u64(l1 + vpn0 * 8, ((pa >> 12) << 10) | flags);
    }

    fn fresh() -> (MemSys, Satp) {
        let ms = MemSys::new(1, BASE, 8 << 20);
        let satp = Satp::make(8, 1, (BASE + 0x10_0000) >> 12);
        (ms, satp)
    }

    #[test]
    fn translates_mapped_page() {
        let (mut ms, satp) = fresh();
        let root = satp.ppn() << 12;
        setup(&mut ms, root, 0x4000_1000, BASE + 0x20_0000, PTE_V | PTE_R | PTE_W | PTE_U);
        let (pa, _) =
            translate(&mut ms, 0, satp, true, 0x4000_1234, Access::Load).unwrap();
        assert_eq!(pa, BASE + 0x20_0234);
        // Second lookup must be a TLB hit (no more ptw accesses).
        let before = ms.evt[0].ptw_accesses;
        translate(&mut ms, 0, satp, true, 0x4000_1000, Access::Load).unwrap();
        assert_eq!(ms.evt[0].ptw_accesses, before);
    }

    #[test]
    fn store_requires_w_and_sets_ad() {
        let (mut ms, satp) = fresh();
        let root = satp.ppn() << 12;
        setup(&mut ms, root, 0x5000_0000, BASE + 0x30_0000, PTE_V | PTE_R | PTE_U);
        assert_eq!(
            translate(&mut ms, 0, satp, true, 0x5000_0000, Access::Store),
            Err(Trap::StorePageFault(0x5000_0000))
        );
        setup(&mut ms, root, 0x5000_0000, BASE + 0x30_0000, PTE_V | PTE_R | PTE_W | PTE_U);
        ms.flush_tlb(0);
        translate(&mut ms, 0, satp, true, 0x5000_0000, Access::Store).unwrap();
        let l1 = root + 0x2000;
        let vpn0 = (0x5000_0000u64 >> 12) & 0x1ff;
        let pte = ms.phys.read_u64(l1 + vpn0 * 8).unwrap();
        assert!(pte & PTE_A != 0 && pte & PTE_D != 0);
    }

    #[test]
    fn unmapped_faults_by_access_kind() {
        let (mut ms, satp) = fresh();
        assert_eq!(
            translate(&mut ms, 0, satp, true, 0x7000_0000, Access::Fetch),
            Err(Trap::InstPageFault(0x7000_0000))
        );
        assert_eq!(
            translate(&mut ms, 0, satp, true, 0x7000_0000, Access::Load),
            Err(Trap::LoadPageFault(0x7000_0000))
        );
    }

    #[test]
    fn non_user_page_faults_in_user_mode() {
        let (mut ms, satp) = fresh();
        let root = satp.ppn() << 12;
        setup(&mut ms, root, 0x4000_0000, BASE + 0x20_0000, PTE_V | PTE_R | PTE_W);
        assert!(translate(&mut ms, 0, satp, true, 0x4000_0000, Access::Load).is_err());
    }

    #[test]
    fn machine_mode_passthrough() {
        let (mut ms, satp) = fresh();
        let (pa, c) = translate(&mut ms, 0, satp, false, 0x1234, Access::Load).unwrap();
        assert_eq!((pa, c), (0x1234, 0));
    }

    #[test]
    fn bad_sign_extension_faults() {
        let (mut ms, satp) = fresh();
        assert!(translate(&mut ms, 0, satp, true, 0x0100_0000_0000_0000, Access::Load).is_err());
    }

    #[test]
    fn tlb_flush_forces_rewalk() {
        let (mut ms, satp) = fresh();
        let root = satp.ppn() << 12;
        setup(&mut ms, root, 0x4000_1000, BASE + 0x20_0000, PTE_V | PTE_R | PTE_U);
        translate(&mut ms, 0, satp, true, 0x4000_1000, Access::Load).unwrap();
        let before = ms.evt[0].tlb_miss;
        ms.flush_tlb(0);
        translate(&mut ms, 0, satp, true, 0x4000_1000, Access::Load).unwrap();
        assert_eq!(ms.evt[0].tlb_miss, before + 1);
    }
}
