//! Evaluation baselines.
//!
//! * Full-system (LiteX/Linux stand-in) — lives in
//!   [`crate::coordinator::target::DirectTarget`]; selected with
//!   `Mode::FullSys`.
//! * Proxy Kernel on an RTL-grade simulator (Chipyard/Verilator stand-in)
//!   — [`pk::PkTarget`] here: single core on the cycle-stepped
//!   [`crate::soc::detailed::DetailedEngine`], host-proxied syscalls with
//!   negligible target-time cost, simulated-DDR timing skew, and a
//!   simulated boot phase (PK runs its init on the simulated CPU, which is
//!   what gives Fig 19(a) its intercept).

pub mod pk;

pub use pk::{run_pk, run_pk_exe, PkConfig};
