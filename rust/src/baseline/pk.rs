//! Proxy-Kernel baseline (paper §VI-E): the same guest ELF running
//! single-core on the cycle-stepped detailed engine, with PK-style
//! host-proxied syscalls (near-instant in target time) and a boot phase
//! executed on the simulated CPU.

use crate::coordinator::runtime::{Kernel, RunConfig, RunResult, Runtime};
use crate::coordinator::target::{ExcInfo, KernelCosts, TargetOps};
use crate::fase::htp::HfOp;
use crate::perf::{Context, Recorder};
use crate::rv64::decode::encode;
use crate::soc::detailed::DetailedEngine;
use crate::soc::machine::DRAM_BASE;
use crate::soc::{Machine, MachineConfig};

#[derive(Debug, Clone)]
pub struct PkConfig {
    /// DDR latency skew vs the FPGA's real memory (simulated DRAM model
    /// differs — the paper's explanation for PK's ~2x error).
    pub dram_skew: i64,
    /// Instructions of PK boot code executed on the simulated CPU before
    /// the workload starts (startup intercept of Fig 19a).
    pub boot_instructions: u64,
    /// PK proxy cost per syscall in target cycles (host handles it; the
    /// target only pays the trap + proxy stub).
    pub proxy_cycles: u64,
    pub core: crate::rv64::hart::CoreModel,
    pub dram_size: u64,
    /// Abstract netlist size (signals evaluated per cycle) — the RTL-sim
    /// slowdown knob; see DESIGN.md §Substitutions.
    pub netlist_size: usize,
    /// Verilator-style simulation threads (scaling saturates ~4).
    pub sim_threads: usize,
    /// Kernel PRNG base seed (see `RunConfig::seed`).
    pub seed: u64,
    /// Execution engine for the underlying [`Machine`]. The PK baseline
    /// cycle-steps through [`DetailedEngine`] so this never drives
    /// execution, but the field keeps the config surface uniform with
    /// [`RunConfig`] so sweep arms can pin it everywhere.
    pub engine: crate::rv64::EngineKind,
}

impl Default for PkConfig {
    fn default() -> Self {
        PkConfig {
            dram_skew: 10,
            boot_instructions: 2_000_000,
            proxy_cycles: 600,
            core: crate::rv64::hart::CoreModel::rocket(),
            dram_size: 1 << 31,
            netlist_size: 2048,
            sim_threads: 1,
            seed: 0xFA5E,
            engine: crate::rv64::EngineKind::default(),
        }
    }
}

/// TargetOps over the detailed engine: same functional ops as the
/// full-system DirectTarget but all time flows through cycle stepping.
pub struct PkTarget {
    pub e: DetailedEngine,
    pub rec: Recorder,
    pub proxy_cycles: u64,
}

impl PkTarget {
    pub fn new(cfg: &PkConfig) -> PkTarget {
        let m = Machine::new(MachineConfig {
            n_harts: 1,
            dram_size: cfg.dram_size,
            clock_hz: 100_000_000,
            core: cfg.core.clone(),
            quantum: 64,
            engine: cfg.engine,
            ..Default::default()
        });
        let mut e = DetailedEngine::with_netlist(m, cfg.dram_skew, cfg.netlist_size, cfg.sim_threads);
        boot(&mut e, cfg.boot_instructions);
        PkTarget { e, rec: Recorder::new(), proxy_cycles: cfg.proxy_cycles }
    }
}

/// Run a PK-style boot loop on the simulated core (touches memory, does
/// arithmetic — crude but it runs *on the engine*, so its wall-clock cost
/// scales with simulator speed exactly like the paper observes).
fn boot(e: &mut DetailedEngine, instructions: u64) {
    let code = DRAM_BASE + 0x100;
    let prog = [
        encode::addi(5, 0, 0),          // t0 = 0
        encode::addi(5, 5, 1),          // loop: t0++
        encode::sd(5, 6, 0),            // store to scratch (x6 pre-set below)
        encode::ld(7, 6, 0),            // load back
        // jal x0, -12 (back to the loop head)
        {
            let off: i64 = -12;
            let v = off as u32;
            0x6fu32
                | (((v >> 20) & 1) << 31)
                | (((v >> 1) & 0x3ff) << 21)
                | (((v >> 11) & 1) << 20)
                | (((v >> 12) & 0xff) << 12)
        },
    ];
    for (i, w) in prog.iter().enumerate() {
        e.m.ms.phys.write_n(code + 4 * i as u64, 4, *w as u64);
    }
    e.m.harts[0].regs[6] = DRAM_BASE + 0x1000; // scratch pointer
    e.m.harts[0].pc = code;
    e.m.harts[0].stop_fetch = false;
    let target = e.retired + instructions;
    while e.retired < target {
        if e.m.harts[0].stop_fetch {
            panic!(
                "PK boot faulted: mcause={} mtval={:#x}",
                e.m.harts[0].csrs.mcause, e.m.harts[0].csrs.mtval
            );
        }
        e.tick();
    }
    // park the core again for the loader
    e.m.harts[0].stop_fetch = true;
    e.m.harts[0].prv = crate::rv64::hart::PrivLevel::M;
    e.m.harts[0].pc = DRAM_BASE;
    e.m.harts[0].regs = [0; 32];
}

impl TargetOps for PkTarget {
    fn n_cpus(&self) -> usize {
        1
    }
    fn clock_hz(&self) -> u64 {
        self.e.m.clock_hz
    }
    fn now(&self) -> u64 {
        self.e.m.now
    }

    fn next_exception(&mut self, t_max: u64) -> Option<ExcInfo> {
        if !self.e.run_until_exception(t_max) {
            return None;
        }
        let ev = self.e.m.pop_exception().unwrap();
        let h = &self.e.m.harts[ev.cpu];
        let cause = h.csrs.mcause;
        Some(ExcInfo {
            cpu: ev.cpu,
            cause,
            epc: h.csrs.mepc,
            tval: h.csrs.mtval,
            at: ev.at,
            nr: if cause == 8 { h.regs[17] } else { 0 },
        })
    }

    fn redirect(&mut self, cpu: usize, pc: u64, _switch: bool) {
        let h = &mut self.e.m.harts[cpu];
        h.csrs.mepc = pc;
        h.csrs.set_mpp(0);
        h.do_mret();
        self.e.m.harts[cpu].stop_fetch = false;
        if self.e.m.harts[cpu].time < self.e.m.now {
            self.e.m.harts[cpu].time = self.e.m.now;
        }
    }

    fn set_mmu(&mut self, cpu: usize, satp: u64) {
        self.e.m.harts[cpu].csrs.satp = satp;
    }
    fn flush_tlb(&mut self, cpu: usize) {
        self.e.m.ms.flush_tlb(cpu);
    }
    fn sync_i(&mut self, cpu: usize) {
        self.e.m.ms.instr_sync(cpu);
        self.e.m.harts[cpu].dcache.clear();
    }
    fn reg_r(&mut self, cpu: usize, idx: u8) -> u64 {
        crate::iface::CpuInterface::reg_read(&mut self.e.m, cpu, idx)
    }
    fn reg_w(&mut self, cpu: usize, idx: u8, val: u64) {
        crate::iface::CpuInterface::reg_write(&mut self.e.m, cpu, idx, val);
    }
    fn mem_r(&mut self, _cpu: usize, paddr: u64) -> u64 {
        self.e.m.ms.phys.read_u64(paddr).unwrap_or(0)
    }
    fn mem_w(&mut self, _cpu: usize, paddr: u64, val: u64) {
        self.e.m.ms.phys.write_u64(paddr, val);
        self.e.m.ms.note_phys_write(paddr, 8);
    }
    fn page_set(&mut self, _cpu: usize, ppn: u64, val: u64) {
        let base = ppn << 12;
        for i in 0..512 {
            self.e.m.ms.phys.write_u64(base + i * 8, val);
        }
        self.e.m.ms.note_phys_write(base, 4096);
    }
    fn page_copy(&mut self, _cpu: usize, src_ppn: u64, dst_ppn: u64) {
        let (s, d) = (src_ppn << 12, dst_ppn << 12);
        for i in 0..512 {
            let v = self.e.m.ms.phys.read_u64(s + i * 8).unwrap_or(0);
            self.e.m.ms.phys.write_u64(d + i * 8, v);
        }
        self.e.m.ms.note_phys_write(d, 4096);
    }
    fn page_read(&mut self, _cpu: usize, ppn: u64) -> Box<[u8; 4096]> {
        let mut p = Box::new([0u8; 4096]);
        p.copy_from_slice(self.e.m.ms.phys.slice(ppn << 12, 4096).unwrap());
        p
    }
    fn page_write(&mut self, _cpu: usize, ppn: u64, data: &[u8; 4096]) {
        self.e.m.ms.phys.slice_mut(ppn << 12, 4096).unwrap().copy_from_slice(data);
        self.e.m.ms.note_phys_write(ppn << 12, 4096);
    }
    fn hfutex(&mut self, _cpu: usize, _op: HfOp, _addr: u64) {}
    fn interrupt(&mut self, cpu: usize) {
        crate::iface::CpuInterface::raise_interrupt(&mut self.e.m, cpu);
    }
    fn tick(&mut self) -> u64 {
        self.e.m.now
    }
    fn utick(&mut self, cpu: usize) -> u64 {
        self.e.m.harts[cpu].utick
    }

    fn syscall_overhead(&mut self, cpu: usize, _nr: u64) {
        // PK proxies to the host: the target pays only the proxy stub.
        let h = &mut self.e.m.harts[cpu];
        if h.time < self.e.m.now {
            h.time = self.e.m.now;
        }
        h.charge(self.proxy_cycles);
        let t = self.e.m.harts[cpu].time;
        self.e.m.now = self.e.m.now.max(t);
        self.rec.record_runtime_stall(self.proxy_cycles);
    }

    fn fault_overhead(&mut self, cpu: usize) {
        self.syscall_overhead(cpu, 0);
    }

    fn advance(&mut self, ticks: u64) {
        let t = self.e.m.now + ticks;
        self.e.run_until(t);
    }

    fn recorder(&mut self) -> &mut Recorder {
        &mut self.rec
    }
    fn set_context(&mut self, ctx: Context) {
        self.rec.set_context(ctx);
    }
    fn machine_mut(&mut self) -> &mut Machine {
        &mut self.e.m
    }
    fn machine(&self) -> &Machine {
        &self.e.m
    }
    fn filtered_wakes(&self) -> u64 {
        0
    }
}

/// Run a guest ELF under the PK baseline; wall-clock in the result is the
/// real cost of RTL-grade simulation on this host.
pub fn run_pk(
    pk: PkConfig,
    elf_path: &std::path::Path,
    argv: &[String],
    envp: &[String],
    max_target_seconds: f64,
) -> RunResult {
    let exe = match crate::elfio::read::Executable::load(elf_path) {
        Ok(exe) => exe,
        Err(e) => {
            return RunResult::empty_with_error(format!(
                "cannot load {}: {e}",
                elf_path.display()
            ))
        }
    };
    run_pk_exe(pk, &exe, argv, envp, max_target_seconds)
}

/// [`run_pk`] for an already-parsed (or synthesized in-memory) executable.
pub fn run_pk_exe(
    pk: PkConfig,
    exe: &crate::elfio::read::Executable,
    argv: &[String],
    envp: &[String],
    max_target_seconds: f64,
) -> RunResult {
    let cfg = RunConfig {
        mode: crate::coordinator::runtime::Mode::FullSys { costs: KernelCosts::default() },
        n_cpus: 1,
        dram_size: pk.dram_size,
        core: pk.core.clone(),
        preload_pages: 16,
        preload_image: true, // PK loads the ELF host-side ("negligible time")
        echo_stdout: false,
        guest_root: std::path::PathBuf::from("."),
        max_target_seconds,
        collect_windows: false,
        htp_batching: true,
        seed: pk.seed,
        engine: pk.engine,
        ..Default::default()
    };
    let target = Box::new(PkTarget::new(&pk));
    let mut rt = Runtime::with_target(cfg, target, false);
    if let Err(e) = rt.load(exe, argv, envp) {
        return RunResult::empty_with_error(e.to_string());
    }
    rt.run()
}

// Unused Kernel import guard (the type appears in docs).
#[allow(unused)]
fn _doc(_k: &Kernel) {}

#[cfg(test)]
mod tests {
    use super::*;

    #[test]
    fn pk_target_boots_on_detailed_engine() {
        let cfg = PkConfig { boot_instructions: 10_000, dram_size: 16 << 20, ..Default::default() };
        let t = PkTarget::new(&cfg);
        assert!(t.e.retired >= 10_000);
        assert!(t.e.m.now > 10_000, "cycle-stepped boot must consume cycles");
        assert!(t.e.m.harts[0].stop_fetch, "parked after boot");
    }

    #[test]
    fn pk_dram_skew_applied() {
        let cfg = PkConfig { boot_instructions: 0, dram_size: 16 << 20, dram_skew: 10, ..Default::default() };
        let t = PkTarget::new(&cfg);
        assert_eq!(t.e.m.ms.lat.dram, crate::mem::MemLatency::default().dram + 10);
    }
}
