//! Cross-session HTP frame coalescing: a deterministic post-hoc replay.
//!
//! Sessions run on private timelines — that is what makes their reports
//! byte-identical solo or packed (DESIGN.md §Serve). Board sharing is
//! therefore modeled *after* the runs: each session's captured
//! [`FrameTrace`] tape is replayed onto a shared board clock, and frames
//! from different sessions whose transmissions overlap merge into one
//! transport transaction. A merged transaction carries every member's
//! wire bytes (channel time is physical and always paid) but one host
//! round-trip charge — the per-request fixed cost PR 8's tag window
//! exists to amortize. The replay is a pure function of the trace set:
//! events sort by (board time, session label, sequence), never by any
//! scheduler state, so stats are byte-stable at any worker count.

use crate::perf::{CoalesceStats, FrameTrace};

/// Frames per merged transaction, bounded by the 7-bit HTP tag window
/// (tag 0 is reserved for the serial path).
pub const TAG_WINDOW: u64 = 127;

/// One completed session's contribution to a board replay.
#[derive(Debug, Clone)]
pub struct SessionTrace {
    /// Stable session label — the deterministic tie-breaker.
    pub label: String,
    /// Board-clock tick at which the session arrived (its frame times
    /// are offsets from this).
    pub start: u64,
    pub frames: Vec<FrameTrace>,
}

/// One flattened replay event.
struct Event<'a> {
    at: u64,
    label: &'a str,
    seq: usize,
    chan: u64,
    host: u64,
}

/// Replay a set of session traces onto one board clock.
///
/// With `coalesce` off every frame is a transaction of its own and pays
/// channel + host serially. With it on, a frame arriving before the
/// current transaction's wire transmission has finished joins it (up to
/// [`TAG_WINDOW`] members); the transaction pays the *maximum* host
/// charge among its members, so `hidden_ticks` — the saved host charges —
/// is exactly `serial_ticks`'s charge total minus the coalesced one.
/// `serial_ticks` is always the uncoalesced makespan, so the on/off
/// saving is readable from a single replay.
pub fn replay(traces: &[SessionTrace], coalesce: bool) -> CoalesceStats {
    let mut events: Vec<Event> = Vec::new();
    for t in traces {
        for (seq, f) in t.frames.iter().enumerate() {
            events.push(Event {
                at: t.start.saturating_add(f.at),
                label: &t.label,
                seq,
                chan: f.chan_ticks,
                host: f.host_ticks,
            });
        }
    }
    events.sort_by(|a, b| (a.at, a.label, a.seq).cmp(&(b.at, b.label, b.seq)));

    let chan_ticks: u64 = events.iter().map(|e| e.chan).sum();
    let host_total: u64 = events.iter().map(|e| e.host).sum();

    // Serial (uncoalesced) makespan: every frame is its own transaction.
    let mut serial = 0u64;
    for e in &events {
        serial = serial.max(e.at) + e.chan + e.host;
    }

    let mut stats = CoalesceStats {
        sessions: traces.len() as u64,
        frames: events.len() as u64,
        transactions: events.len() as u64,
        merged_frames: 0,
        hidden_ticks: 0,
        board_ticks: serial,
        serial_ticks: serial,
        chan_ticks,
        peak_occupancy: u64::from(!events.is_empty()),
        admission_waits: 0,
    };
    if !coalesce || events.is_empty() {
        return stats;
    }

    // Coalesced pass: greedy window merge. A transaction stays open
    // while its wire transmission runs; frames arriving inside that
    // window append their bytes (extending the window) until the tag
    // budget is spent. The host charge is paid once, on close.
    let mut board = 0u64;
    let mut transactions = 0u64;
    let mut charged_host = 0u64;
    let mut peak = 0u64;
    let mut open: Option<(u64, u64)> = None; // (host_max, occupancy)
    let mut window_end = 0u64;
    for e in &events {
        match &mut open {
            Some((host_max, occ)) if e.at <= window_end && *occ < TAG_WINDOW => {
                board += e.chan;
                window_end = board;
                *host_max = (*host_max).max(e.host);
                *occ += 1;
                peak = peak.max(*occ);
            }
            _ => {
                if let Some((host_max, _)) = open.take() {
                    board += host_max;
                    charged_host += host_max;
                }
                board = board.max(e.at) + e.chan;
                window_end = board;
                open = Some((e.host, 1));
                peak = peak.max(1);
                transactions += 1;
            }
        }
    }
    if let Some((host_max, _)) = open {
        board += host_max;
        charged_host += host_max;
    }
    stats.transactions = transactions;
    stats.merged_frames = stats.frames - transactions;
    stats.hidden_ticks = host_total - charged_host;
    stats.board_ticks = board;
    stats.peak_occupancy = peak;
    stats
}

#[cfg(test)]
mod tests {
    use super::*;

    fn frame(at: u64, chan: u64, host: u64) -> FrameTrace {
        FrameTrace { at, chan_ticks: chan, host_ticks: host, bytes: 8 }
    }

    fn session(label: &str, start: u64, frames: Vec<FrameTrace>) -> SessionTrace {
        SessionTrace { label: label.into(), start, frames }
    }

    #[test]
    fn empty_replay_is_all_zeros() {
        let s = replay(&[], true);
        assert_eq!(s.frames, 0);
        assert_eq!(s.board_ticks, 0);
        assert_eq!(s.peak_occupancy, 0);
    }

    #[test]
    fn solo_session_coalesces_nothing_new_across_gaps() {
        // Frames far apart: each transaction closes before the next
        // arrives, so on == off.
        let t = vec![session("a", 0, vec![frame(0, 10, 50), frame(1000, 10, 50)])];
        let on = replay(&t, true);
        let off = replay(&t, false);
        assert_eq!(on.merged_frames, 0);
        assert_eq!(on.board_ticks, off.board_ticks);
        assert_eq!(on.hidden_ticks, 0);
    }

    #[test]
    fn overlapping_sessions_merge_and_strictly_save() {
        // Two sessions issuing at the same instants: every pair of
        // frames overlaps on the wire, so half the host charges vanish.
        let mk = |label: &str| {
            session(label, 0, vec![frame(0, 10, 50), frame(5, 10, 50), frame(12, 10, 50)])
        };
        let t = vec![mk("a"), mk("b")];
        let on = replay(&t, true);
        let off = replay(&t, false);
        assert_eq!(off.transactions, 6);
        assert!(on.transactions < 6, "overlapping frames must merge");
        assert!(on.merged_frames > 0);
        assert!(on.board_ticks < off.board_ticks, "{} !< {}", on.board_ticks, off.board_ticks);
        assert_eq!(on.serial_ticks, off.board_ticks);
        assert!(on.hidden_ticks > 0);
        assert!(on.peak_occupancy >= 2);
        // Channel time is physical: identical either way.
        assert_eq!(on.chan_ticks, off.chan_ticks);
    }

    #[test]
    fn replay_is_order_independent() {
        let a = session("a", 0, vec![frame(0, 10, 50), frame(40, 10, 50)]);
        let b = session("b", 3, vec![frame(0, 10, 50)]);
        let fwd = replay(&[a.clone(), b.clone()], true);
        let rev = replay(&[b, a], true);
        assert_eq!(fwd.board_ticks, rev.board_ticks);
        assert_eq!(fwd.merged_frames, rev.merged_frames);
        assert_eq!(fwd.hidden_ticks, rev.hidden_ticks);
    }

    #[test]
    fn tag_window_caps_a_transaction() {
        // 200 frames all at t=0 with zero channel time would merge into
        // one unbounded transaction; the 127-tag window forces a split.
        let frames: Vec<FrameTrace> = (0..200).map(|_| frame(0, 0, 10)).collect();
        let s = replay(&[session("a", 0, frames)], true);
        assert_eq!(s.transactions, 2);
        assert_eq!(s.peak_occupancy, TAG_WINDOW);
    }

    #[test]
    fn arrival_offsets_shift_sessions_apart() {
        // A huge stagger separates the sessions entirely: no merges
        // across the gap.
        let mk = |label: &str, start: u64| session(label, start, vec![frame(0, 10, 50)]);
        let s = replay(&[mk("a", 0), mk("b", 1_000_000)], true);
        assert_eq!(s.merged_frames, 0);
        assert_eq!(s.board_ticks, s.serial_ticks);
    }
}
