//! One serve session: an isolated guest run with its own `Runtime`,
//! address space and PRNG stream, identified by a stable label.
//!
//! A session *is* a sweep scenario — its atom is the scenario label
//! grammar (`workload|arm|<harts>c|core|s<seed>`) and its PRNG stream is
//! the same label-keyed derivation sweep jobs use. That shared identity
//! is the determinism contract: a session's report is a pure function of
//! (daemon base spec, session label), so the same atom submitted solo,
//! packed 16-deep, or spread across boards produces byte-identical
//! report bytes (docs/serve.md).

use crate::coordinator::runtime::{run_elf, run_exe, RunResult};
use crate::rv64::hart::CoreModel;
use crate::sweep::job::{find_guest_elf, JobOutcome};
use crate::sweep::report::job_report_json;
use crate::sweep::spec::{Arm, SweepSpec, WorkloadKind, WorkloadSpec};
use crate::sweep::{synth, Job};

/// A parsed, runnable session.
#[derive(Debug, Clone)]
pub struct Session {
    pub job: Job,
    /// Bytes delivered to the guest's blocking stdin at the
    /// deterministic all-parked point (`Runtime::push_stdin`).
    pub stdin: Vec<u8>,
}

/// A completed session: the full outcome plus the canonical report bytes
/// clients receive (and CI `cmp`-gates against solo runs).
pub struct SessionOutcome {
    pub label: String,
    pub outcome: JobOutcome,
    pub report: String,
}

impl Session {
    /// Parse a session atom against the daemon's base spec. The atom is
    /// a full scenario label; the round trip through [`Job::label`] must
    /// be exact, so axis-pin suffixes (`+block`, `+o8`, `+x4`, ...) are
    /// rejected — serve sessions are always solo scenarios.
    pub fn parse(atom: &str, base: &SweepSpec) -> Result<Session, String> {
        let parts: Vec<&str> = atom.trim().split('|').collect();
        let [workload, arm, harts, core, seed] = parts.as_slice() else {
            return Err(format!(
                "bad session atom {atom:?}: want workload|arm|<harts>c|core|s<seed>"
            ));
        };
        let workload = WorkloadSpec::parse(workload)
            .ok_or_else(|| format!("bad workload atom {workload:?}"))?;
        let arm = Arm::parse(arm).ok_or_else(|| format!("bad arm {arm:?}"))?;
        if matches!(arm, Arm::Pk { .. }) {
            return Err("pk arms are not servable (detached cycle-stepped runs only)".into());
        }
        let harts: usize = harts
            .strip_suffix('c')
            .and_then(|n| n.parse().ok())
            .filter(|&n| (1..=64).contains(&n))
            .ok_or_else(|| format!("bad hart count {harts:?}: want 1c..64c"))?;
        let seed: u64 = seed
            .strip_prefix('s')
            .and_then(|n| n.parse().ok())
            .ok_or_else(|| format!("bad seed {seed:?}: want s<N>"))?;
        let job = Job::new(0, workload, arm, harts, core.to_string(), seed, None, None, base);
        if job.label() != atom.trim() {
            return Err(format!(
                "session atom {atom:?} is not canonical (parsed back as {:?})",
                job.label()
            ));
        }
        Ok(Session { job, stdin: Vec::new() })
    }

    pub fn with_stdin(mut self, stdin: Vec<u8>) -> Session {
        self.stdin = stdin;
        self
    }

    pub fn label(&self) -> String {
        self.job.label()
    }

    /// Run the session to completion on a private timeline, frame trace
    /// armed for the board replay.
    pub fn run(&self) -> SessionOutcome {
        let result = run_session(&self.job, &self.stdin);
        let score = match self.job.workload.metric_prefix() {
            Some(prefix) if result.error.is_none() => result.parse_metric(prefix),
            _ => None,
        };
        let outcome = JobOutcome { job: self.job.clone(), result, score, analysis: None };
        let report = session_report(&outcome);
        SessionOutcome { label: self.job.label(), outcome, report }
    }
}

/// The canonical per-session report bytes: exactly the job object a
/// sweep report would contain for the same scenario, pretty-printed.
/// Frame traces and board stats never appear (the trace is invisible to
/// metrics and `coalesce` attaches only to sessions-pinned sweep cells),
/// which is what keeps these bytes packing-invariant.
pub fn session_report(outcome: &JobOutcome) -> String {
    job_report_json(outcome).to_string_pretty()
}

/// Execute a session's job with stdin and frame tracing threaded in —
/// the serve-layer sibling of `sweep::run_job` for the non-PK arms.
pub(crate) fn run_session(job: &Job, stdin: &[u8]) -> RunResult {
    let Some(core) = CoreModel::by_name(&job.core) else {
        return RunResult::empty_with_error(format!("unknown core model {:?}", job.core));
    };
    let (synth, argv) = match &job.workload.kind {
        WorkloadKind::Synth(_) => (true, vec![job.workload.name.clone()]),
        WorkloadKind::Gapbs { bench, scale, trials } => (
            false,
            vec![bench.clone(), scale.to_string(), job.harts.to_string(), trials.to_string()],
        ),
        WorkloadKind::Coremark { iters } => {
            (false, vec!["coremark".to_string(), iters.to_string()])
        }
    };
    let mut cfg = job.run_config(core, synth);
    cfg.stdin = stdin.to_vec();
    cfg.trace_frames = true;
    match &job.workload.kind {
        WorkloadKind::Synth(kind) => run_exe(cfg, &synth::build(*kind), &argv, &[]),
        _ => match find_guest_elf(&argv[0]) {
            Ok(elf) => run_elf(cfg, &elf, &argv, &[]),
            Err(e) => RunResult::empty_with_error(e),
        },
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> SweepSpec {
        let mut spec = SweepSpec::new("serve");
        spec.seed = 0xFA5E;
        spec.dram_size = 64 << 20;
        spec.max_target_seconds = 30.0;
        spec
    }

    #[test]
    fn atom_round_trips_through_parse() {
        let s = Session::parse("echo:64|fase@uart:921600|1c|rocket|s3", &base()).unwrap();
        assert_eq!(s.label(), "echo:64|fase@uart:921600|1c|rocket|s3");
        assert_eq!(s.job.seed, 3);
        assert_eq!(s.job.harts, 1);
    }

    #[test]
    fn bad_atoms_are_rejected() {
        let b = base();
        for atom in [
            "echo:64",                                  // not a full label
            "nope:1|fullsys|1c|rocket|s0",              // unknown workload
            "spin:10|warp@9|1c|rocket|s0",              // unknown arm
            "spin:10|pk-4t|1c|rocket|s0",               // PK not servable
            "spin:10|fullsys|0c|rocket|s0",             // bad harts
            "spin:10|fullsys|1c|rocket|zz",             // bad seed
            "spin:10|fullsys+block|1c|rocket|s0",       // pins rejected
            " spin:10 |fullsys|1c|rocket|s0",           // non-canonical
        ] {
            assert!(Session::parse(atom, &b).is_err(), "{atom:?} should not parse");
        }
    }

    #[test]
    fn session_stream_is_a_pure_function_of_its_label() {
        let b = base();
        let a = Session::parse("spin:10|fullsys|1c|rocket|s0", &b).unwrap();
        let a2 = Session::parse("spin:10|fullsys|1c|rocket|s0", &b).unwrap();
        let c = Session::parse("spin:10|fullsys|1c|rocket|s1", &b).unwrap();
        assert_eq!(a.job.prng_seed, a2.job.prng_seed);
        assert_ne!(a.job.prng_seed, c.job.prng_seed);
    }

    #[test]
    fn echo_session_runs_with_stdin_and_reports() {
        let s = Session::parse("echo:64|fase@uart:921600|1c|rocket|s0", &base())
            .unwrap()
            .with_stdin(b"ping".to_vec());
        let out = s.run();
        assert!(out.outcome.ok(), "{:?}", out.outcome.result.error);
        assert_eq!(out.outcome.result.stdout, "ping");
        assert!(!out.outcome.result.frames.is_empty(), "frame trace must be armed");
        assert!(out.report.contains("\"label\": \"echo:64|fase@uart:921600|1c|rocket|s0\""));
        assert!(!out.report.contains("coalesce"), "per-session reports never carry board stats");
    }
}
