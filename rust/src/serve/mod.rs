//! Multi-tenant serving: a board pool multiplexing many concurrent
//! sessions, with cross-session HTP frame coalescing (DESIGN.md §Serve).
//!
//! The layer has four parts:
//!
//! * [`session`] — one session: an isolated `Runtime` + address space
//!   with a label-keyed PRNG stream, run on a private timeline.
//! * [`coalesce`] — the deterministic board replay that merges
//!   overlapping frames from co-resident sessions into shared transport
//!   transactions (one host charge per merged transaction).
//! * [`boardpool`] — N boards, M >> N sessions: label-keyed board
//!   assignment, counting-gate admission with a bounded queue.
//! * [`server`] — the `fase serve` TCP daemon and `fase submit` client.
//!
//! Determinism contract: a session's report depends only on (base spec,
//! session label, stdin) — board packing shifts *when* a session runs,
//! never *what* it computes, because sharing is modeled by replaying
//! captured frame traces after the fact rather than by interleaving live
//! machines. The serve-axis sweep cells (`sessions`/`arrivals`/
//! `coalesces`, `+xN+aN+cB` labels) reuse the same replay via
//! [`run_batch_job`].

pub mod boardpool;
pub mod coalesce;
pub mod server;
pub mod session;

pub use boardpool::{BoardLease, BoardPool, Busy};
pub use coalesce::{replay, SessionTrace, TAG_WINDOW};
pub use server::{serve_blocking, start, submit, ServeConfig, ServerHandle};
pub use session::{Session, SessionOutcome};

use crate::coordinator::runtime::{run_exe, RunResult};
use crate::elfio::read::Executable;
use crate::rv64::hart::CoreModel;
use crate::sweep::job::{session_seed, Job};

/// Target clock ticks per microsecond (the 100 MHz HTP clock) — converts
/// the arrival-axis stagger into board-clock offsets.
const TICKS_PER_US: u64 = 100;

/// Run a sessions-pinned sweep cell: N replica sessions of the same
/// synthetic scenario packed on one board, arrivals staggered by the
/// `+aN` pin, frames replayed through the coalescer per the `+cB` pin.
///
/// Replica `k` is the session labeled `<job label>#k` with the stream
/// `session_seed(job.prng_seed, that label)` — a pure function of the
/// cell identity, so the cell's report is byte-stable at any worker
/// count. The returned result is replica 0's run annotated with the
/// board's [`crate::perf::CoalesceStats`]; replica labels carry distinct
/// seeds, so the board tallies are extra members on a distinct label,
/// which keeps solo cells' gated metrics untouched.
pub fn run_batch_job(job: &Job, core: CoreModel, exe: &Executable, argv: &[String]) -> RunResult {
    let n = job.sessions() as usize;
    let base_label = job.label();
    let stagger = job.arrival_us() * TICKS_PER_US;
    let mut traces = Vec::with_capacity(n);
    let mut first: Option<RunResult> = None;
    for k in 0..n {
        let label = format!("{base_label}#{k}");
        let mut cfg = job.run_config(core.clone(), true);
        cfg.seed = session_seed(job.prng_seed, &label);
        cfg.trace_frames = true;
        let r = run_exe(cfg, exe, argv, &[]);
        if r.error.is_some() {
            return r; // one broken replica fails the whole cell
        }
        traces.push(SessionTrace { label, start: k as u64 * stagger, frames: r.frames.clone() });
        if first.is_none() {
            first = Some(r);
        }
    }
    let stats = coalesce::replay(&traces, job.coalesce());
    let mut result = first.expect("sessions() >= 1 ran at least one replica");
    result.coalesce = Some(stats);
    result
}

#[cfg(test)]
mod tests {
    use super::*;
    use crate::sweep::spec::{Arm, SweepSpec, SynthKind, WorkloadSpec};
    use crate::sweep::{run_job, Job};

    fn storm_cell(sessions: u32, arrival_us: u64, coalesce: bool) -> Job {
        let mut spec = SweepSpec::new("serve-batch");
        spec.seed = 0xFA5E;
        spec.dram_size = 64 << 20;
        spec.max_target_seconds = 30.0;
        let mut job = Job::new(
            0,
            WorkloadSpec::synth(SynthKind::Storm { calls: 64 }),
            Arm::fase_uart(921_600),
            1,
            "rocket".into(),
            0,
            None,
            None,
            &spec,
        );
        job.set_serve_pins(Some(sessions), Some(arrival_us), Some(coalesce), &spec);
        job
    }

    #[test]
    fn batch_cell_attaches_board_stats_and_coalescing_saves_ticks() {
        let on = run_job(&storm_cell(4, 0, true));
        let off = run_job(&storm_cell(4, 0, false));
        assert!(on.ok(), "{:?}", on.result.error);
        assert!(off.ok(), "{:?}", off.result.error);
        let s_on = on.result.coalesce.as_ref().expect("board stats attach");
        let s_off = off.result.coalesce.as_ref().expect("board stats attach");
        assert_eq!(s_on.sessions, 4);
        assert_eq!(s_on.frames, s_off.frames);
        assert!(s_on.merged_frames > 0, "storm x4 must overlap on the wire");
        assert!(
            s_on.board_ticks < s_off.board_ticks,
            "coalescing must strictly reduce board ticks: {} !< {}",
            s_on.board_ticks,
            s_off.board_ticks
        );
        assert_eq!(s_off.board_ticks, s_off.serial_ticks);
        assert!(s_on.hidden_ticks > 0);
    }

    #[test]
    fn packing_never_changes_a_replicas_own_metrics() {
        // The pinned cell's own run (replica 0) must match a direct solo
        // run with the same label-derived stream: packing is replay-only.
        let job = storm_cell(2, 100, true);
        let out = run_job(&job);
        assert!(out.ok());
        let core = crate::rv64::hart::CoreModel::by_name("rocket").unwrap();
        let exe = crate::sweep::synth::build(SynthKind::Storm { calls: 64 });
        let mut cfg = job.run_config(core, true);
        cfg.seed = session_seed(job.prng_seed, &format!("{}#0", job.label()));
        cfg.trace_frames = true;
        let solo = crate::coordinator::runtime::run_exe(
            cfg,
            &exe,
            &[job.workload.name.clone()],
            &[],
        );
        assert_eq!(out.result.ticks, solo.ticks);
        assert_eq!(out.result.instret, solo.instret);
        assert_eq!(out.result.frames, solo.frames);
    }
}
