//! The board pool: N targets multiplexed over M >> N sessions.
//!
//! Admission control is a counting gate with a bounded wait queue:
//! up to `max_sessions` sessions run concurrently, up to `queue_cap`
//! more block waiting for a slot, and anything beyond that is rejected
//! with a retry hint (`BUSY <retry_ms>` on the wire) — backpressure
//! instead of unbounded queueing. Board *assignment* is a pure function
//! of the session label (its FNV hash mod the board count), so which
//! board a session's frames land on never depends on scheduling — the
//! property that keeps per-board coalescing stats deterministic given
//! the set of completed sessions.

use super::coalesce::{self, SessionTrace};
use crate::perf::FrameTrace;
use crate::sweep::job::session_seed;
use crate::util::json::Json;
use std::sync::{Condvar, Mutex};

/// Admission rejection: the run queue is full.
#[derive(Debug, Clone, Copy, PartialEq, Eq)]
pub struct Busy {
    /// Suggested client backoff before resubmitting.
    pub retry_after_ms: u64,
}

struct State {
    active: usize,
    queued: usize,
    /// Sessions that had to wait for a slot (the admission_waits stat).
    waits: u64,
    completed: u64,
    /// Per-board tapes of completed sessions, replayed for STATS.
    tapes: Vec<Vec<SessionTrace>>,
}

pub struct BoardPool {
    boards: usize,
    max_sessions: usize,
    queue_cap: usize,
    inner: Mutex<State>,
    cv: Condvar,
}

/// A granted run slot. Dropping it frees the slot and wakes a waiter;
/// [`BoardPool::record`] files the session's trace on its board first.
pub struct BoardLease<'a> {
    pool: &'a BoardPool,
    /// The board this session's frames replay onto.
    pub board: usize,
}

impl Drop for BoardLease<'_> {
    fn drop(&mut self) {
        let mut st = self.pool.inner.lock().unwrap();
        st.active -= 1;
        drop(st);
        self.pool.cv.notify_one();
    }
}

impl BoardPool {
    pub fn new(boards: usize, max_sessions: usize, queue_cap: usize) -> BoardPool {
        let boards = boards.max(1);
        BoardPool {
            boards,
            max_sessions: max_sessions.max(1),
            queue_cap,
            inner: Mutex::new(State {
                active: 0,
                queued: 0,
                waits: 0,
                completed: 0,
                tapes: vec![Vec::new(); boards],
            }),
            cv: Condvar::new(),
        }
    }

    /// Deterministic label-keyed board assignment.
    pub fn board_for(&self, label: &str) -> usize {
        (session_seed(0, label) % self.boards as u64) as usize
    }

    /// Admit a session, blocking in the bounded queue if all slots are
    /// busy. Returns [`Busy`] when the queue is full too.
    pub fn admit(&self, label: &str) -> Result<BoardLease<'_>, Busy> {
        let board = self.board_for(label);
        let mut st = self.inner.lock().unwrap();
        if st.active >= self.max_sessions {
            if st.queued >= self.queue_cap {
                return Err(Busy { retry_after_ms: 50 });
            }
            st.queued += 1;
            st.waits += 1;
            while st.active >= self.max_sessions {
                st = self.cv.wait(st).unwrap();
            }
            st.queued -= 1;
        }
        st.active += 1;
        Ok(BoardLease { pool: self, board })
    }

    /// File a completed session's frame trace on its board. Arrival
    /// offsets are all zero: daemon stats are a function of the *set* of
    /// completed sessions, never of wall-clock arrival order.
    pub fn record(&self, lease: &BoardLease<'_>, label: String, frames: Vec<FrameTrace>) {
        let mut st = self.inner.lock().unwrap();
        st.completed += 1;
        st.tapes[lease.board].push(SessionTrace { label, start: 0, frames });
    }

    /// Sessions that had to wait for a slot so far.
    pub fn waits(&self) -> u64 {
        self.inner.lock().unwrap().waits
    }

    /// Currently queued sessions (test hook for the admission path).
    pub fn queued(&self) -> usize {
        self.inner.lock().unwrap().queued
    }

    /// Replay every board's tape and assemble the STATS document.
    pub fn stats_json(&self, coalesce: bool) -> Json {
        let st = self.inner.lock().unwrap();
        let mut boards = Vec::new();
        for tape in &st.tapes {
            let mut tape: Vec<SessionTrace> = tape.clone();
            tape.sort_by(|a, b| a.label.cmp(&b.label));
            let mut s = coalesce::replay(&tape, coalesce);
            s.admission_waits = st.waits;
            boards.push(s.to_json());
        }
        Json::Obj(vec![
            ("boards".into(), Json::Arr(boards)),
            ("sessions_completed".into(), Json::u64(st.completed)),
            ("admission_waits".into(), Json::u64(st.waits)),
            ("coalesce".into(), Json::Bool(coalesce)),
        ])
    }
}

#[cfg(test)]
mod tests {
    use super::*;
    use std::sync::atomic::{AtomicBool, Ordering};

    #[test]
    fn board_assignment_is_stable_and_in_range() {
        let p = BoardPool::new(4, 8, 8);
        let b = p.board_for("echo:64|fullsys|1c|rocket|s0");
        assert_eq!(b, p.board_for("echo:64|fullsys|1c|rocket|s0"));
        assert!(b < 4);
    }

    #[test]
    fn queue_full_is_busy_not_a_hang() {
        let p = BoardPool::new(1, 1, 0);
        let lease = p.admit("a").unwrap();
        assert_eq!(p.admit("b").err(), Some(Busy { retry_after_ms: 50 }));
        drop(lease);
        assert!(p.admit("b").is_ok());
    }

    #[test]
    fn m_plus_first_session_queues_then_completes_when_a_slot_frees() {
        // Capacity 1, queue 4: the second session must wait in the
        // admission queue and proceed — not error — once the first
        // session's lease drops.
        let p = BoardPool::new(1, 1, 4);
        let second_ran = AtomicBool::new(false);
        std::thread::scope(|s| {
            let first = p.admit("first").unwrap();
            s.spawn(|| {
                let lease = p.admit("second").unwrap();
                second_ran.store(true, Ordering::SeqCst);
                p.record(&lease, "second".into(), Vec::new());
            });
            // Wait until the second session is visibly parked in the queue.
            while p.queued() == 0 {
                std::thread::yield_now();
            }
            assert!(!second_ran.load(Ordering::SeqCst));
            drop(first); // free the slot; the waiter takes it
        });
        assert!(second_ran.load(Ordering::SeqCst));
        assert_eq!(p.waits(), 1);
        assert_eq!(p.queued(), 0);
    }

    #[test]
    fn stats_replay_each_board_deterministically() {
        let p = BoardPool::new(2, 4, 4);
        let a = p.admit("a").unwrap();
        p.record(
            &a,
            "a".into(),
            vec![FrameTrace { at: 0, chan_ticks: 10, host_ticks: 50, bytes: 8 }],
        );
        drop(a);
        let j = p.stats_json(true);
        assert_eq!(j.get("sessions_completed").unwrap().as_f64(), Some(1.0));
        assert_eq!(j.get("boards").unwrap().as_arr().unwrap().len(), 2);
    }
}
