//! The `fase serve` daemon: a line-oriented TCP control surface over the
//! board pool, plus the `fase submit` client (docs/serve.md).
//!
//! Protocol (one request per connection, ASCII header lines, raw bodies):
//!
//! ```text
//! -> RUN <label> <stdin_len>\n<stdin bytes>
//! <- OK <label> <report_len>\n<report bytes>     session ran
//! <- BUSY <retry_ms>\n                           queue full, back off
//! <- ERR <message>\n                             bad atom / run error
//!
//! -> STATS\n
//! <- OK stats <len>\n<json>                      per-board coalescing stats
//!
//! -> SHUTDOWN\n
//! <- OK bye\n                                    daemon drains and exits
//! ```
//!
//! Every session runs to completion inside its connection's thread; the
//! reply carries the canonical per-session report bytes, which are a
//! pure function of (base spec, label [, stdin]) — never of what else
//! the daemon is running. That is the property the CI smoke `cmp`-gates.

use super::boardpool::BoardPool;
use super::session::Session;
use crate::sweep::spec::SweepSpec;
use std::io::{BufRead, BufReader, Read, Write};
use std::net::{SocketAddr, TcpListener, TcpStream};
use std::sync::atomic::{AtomicBool, Ordering};
use std::sync::Arc;

/// Daemon configuration (`fase serve` flags).
#[derive(Clone)]
pub struct ServeConfig {
    /// Bind address; port 0 picks a free port (tests, CI).
    pub addr: String,
    pub boards: usize,
    pub max_sessions: usize,
    /// Admission queue bound; beyond it clients get `BUSY`.
    pub queue_cap: usize,
    /// Whether board replays coalesce cross-session frames.
    pub coalesce: bool,
    /// Base spec sessions derive their config from (seed, dram,
    /// max_seconds — the axes a label does not carry).
    pub base: SweepSpec,
}

impl ServeConfig {
    pub fn new(base: SweepSpec) -> ServeConfig {
        ServeConfig {
            addr: "127.0.0.1:0".into(),
            boards: 1,
            max_sessions: 4,
            queue_cap: 16,
            coalesce: true,
            base,
        }
    }
}

struct Shared {
    cfg: ServeConfig,
    pool: BoardPool,
    stop: AtomicBool,
    /// The listener's bound address (the self-connect shutdown nudge).
    addr: SocketAddr,
}

/// A running daemon. The listener thread exits after `SHUTDOWN` (or
/// [`ServerHandle::shutdown`]); in-flight sessions finish first because
/// each runs on its own connection thread joined via scoped ownership.
pub struct ServerHandle {
    pub addr: SocketAddr,
    accept: Option<std::thread::JoinHandle<()>>,
}

impl ServerHandle {
    /// Ask the daemon to stop and wait for the listener to exit.
    pub fn shutdown(mut self) {
        let _ = shutdown(&self.addr.to_string());
        if let Some(h) = self.accept.take() {
            let _ = h.join();
        }
    }

    pub fn stats(&self) -> Result<String, String> {
        stats(&self.addr.to_string())
    }
}

/// Fetch a running daemon's per-board coalescing stats (`fase submit
/// --stats`).
pub fn stats(addr: &str) -> Result<String, String> {
    match submit_raw(addr, "STATS\n", &[])? {
        Reply::Ok { body, .. } => Ok(body),
        other => Err(format!("unexpected STATS reply: {other:?}")),
    }
}

/// Ask a running daemon to drain and exit (`fase submit --shutdown`).
pub fn shutdown(addr: &str) -> Result<(), String> {
    submit_raw(addr, "SHUTDOWN\n", &[]).map(|_| ())
}

/// Bind and start serving in background threads.
pub fn start(cfg: ServeConfig) -> std::io::Result<ServerHandle> {
    let listener = TcpListener::bind(&cfg.addr)?;
    let addr = listener.local_addr()?;
    let shared = Arc::new(Shared {
        pool: BoardPool::new(cfg.boards, cfg.max_sessions, cfg.queue_cap),
        cfg,
        stop: AtomicBool::new(false),
        addr,
    });
    let accept = {
        let shared = Arc::clone(&shared);
        std::thread::spawn(move || {
            let mut workers = Vec::new();
            for conn in listener.incoming() {
                if shared.stop.load(Ordering::SeqCst) {
                    break;
                }
                let Ok(stream) = conn else { continue };
                let shared = Arc::clone(&shared);
                workers.push(std::thread::spawn(move || handle(stream, &shared)));
            }
            for w in workers {
                let _ = w.join();
            }
        })
    };
    Ok(ServerHandle { addr, accept: Some(accept) })
}

/// Serve until shutdown (the `fase serve` CLI entry: blocks forever).
pub fn serve_blocking(cfg: ServeConfig) -> std::io::Result<()> {
    let mut h = start(cfg)?;
    println!("LISTENING {}", h.addr);
    if let Some(t) = h.accept.take() {
        let _ = t.join();
    }
    Ok(())
}

fn handle(mut stream: TcpStream, shared: &Shared) {
    let peer = stream.try_clone();
    let mut reader = BufReader::new(match peer {
        Ok(s) => s,
        Err(_) => return,
    });
    let mut line = String::new();
    if reader.read_line(&mut line).is_err() {
        return;
    }
    let line = line.trim_end();
    let reply = |stream: &mut TcpStream, head: String, body: &[u8]| {
        let _ = stream.write_all(head.as_bytes());
        let _ = stream.write_all(body);
        let _ = stream.flush();
    };
    if line == "SHUTDOWN" {
        shared.stop.store(true, Ordering::SeqCst);
        reply(&mut stream, "OK bye\n".into(), &[]);
        // Self-connect to unblock the accept loop if we were the only
        // connection in flight.
        let _ = TcpStream::connect(shared.addr);
        return;
    }
    if line == "STATS" {
        let body = shared.pool.stats_json(shared.cfg.coalesce).to_string_pretty();
        reply(&mut stream, format!("OK stats {}\n", body.len()), body.as_bytes());
        return;
    }
    let Some(rest) = line.strip_prefix("RUN ") else {
        reply(&mut stream, format!("ERR bad request {line:?}\n"), &[]);
        return;
    };
    let (label, stdin_len) = match rest.rsplit_once(' ') {
        Some((l, n)) => match n.parse::<usize>() {
            Ok(n) if n <= 1 << 20 => (l.to_string(), n),
            _ => {
                reply(&mut stream, format!("ERR bad stdin length {n:?}\n"), &[]);
                return;
            }
        },
        None => (rest.to_string(), 0),
    };
    let mut stdin = vec![0u8; stdin_len];
    if reader.read_exact(&mut stdin).is_err() {
        reply(&mut stream, "ERR short stdin body\n".into(), &[]);
        return;
    }
    let session = match Session::parse(&label, &shared.cfg.base) {
        Ok(s) => s.with_stdin(stdin),
        Err(e) => {
            reply(&mut stream, format!("ERR {e}\n"), &[]);
            return;
        }
    };
    let lease = match shared.pool.admit(&label) {
        Ok(l) => l,
        Err(busy) => {
            reply(&mut stream, format!("BUSY {}\n", busy.retry_after_ms), &[]);
            return;
        }
    };
    let out = session.run();
    shared.pool.record(&lease, out.label.clone(), out.outcome.result.frames.clone());
    drop(lease);
    reply(
        &mut stream,
        format!("OK {} {}\n", out.label, out.report.len()),
        out.report.as_bytes(),
    );
}

/// A parsed daemon reply.
#[derive(Debug)]
pub enum Reply {
    Ok { label: String, body: String },
    Busy { retry_after_ms: u64 },
    Err(String),
}

fn submit_raw(addr: &str, request: &str, body: &[u8]) -> Result<Reply, String> {
    let mut stream = TcpStream::connect(addr).map_err(|e| format!("connect {addr}: {e}"))?;
    stream.write_all(request.as_bytes()).map_err(|e| e.to_string())?;
    stream.write_all(body).map_err(|e| e.to_string())?;
    stream.flush().map_err(|e| e.to_string())?;
    let mut reader = BufReader::new(stream);
    let mut head = String::new();
    reader.read_line(&mut head).map_err(|e| e.to_string())?;
    let head = head.trim_end();
    if let Some(ms) = head.strip_prefix("BUSY ") {
        return Ok(Reply::Busy { retry_after_ms: ms.parse().unwrap_or(50) });
    }
    if let Some(msg) = head.strip_prefix("ERR ") {
        return Ok(Reply::Err(msg.to_string()));
    }
    let Some(rest) = head.strip_prefix("OK ") else {
        return Err(format!("malformed reply header {head:?}"));
    };
    let (label, len) = match rest.rsplit_once(' ') {
        Some((l, n)) => (l.to_string(), n.parse::<usize>().map_err(|e| e.to_string())?),
        None => (rest.to_string(), 0),
    };
    let mut body = vec![0u8; len];
    reader.read_exact(&mut body).map_err(|e| e.to_string())?;
    Ok(Reply::Ok { label, body: String::from_utf8_lossy(&body).into_owned() })
}

/// Submit one session and return its report bytes. Retries `BUSY`
/// replies (honoring the daemon's backoff hint) until `deadline_ms`
/// elapses; protocol-level `ERR` replies are terminal.
pub fn submit(addr: &str, label: &str, stdin: &[u8], deadline_ms: u64) -> Result<String, String> {
    let request = format!("RUN {label} {}\n", stdin.len());
    let mut waited = 0u64;
    loop {
        match submit_raw(addr, &request, stdin)? {
            Reply::Ok { body, .. } => return Ok(body),
            Reply::Err(e) => return Err(e),
            Reply::Busy { retry_after_ms } => {
                if waited >= deadline_ms {
                    return Err(format!("daemon busy after {waited}ms"));
                }
                std::thread::sleep(std::time::Duration::from_millis(retry_after_ms));
                waited += retry_after_ms;
            }
        }
    }
}

#[cfg(test)]
mod tests {
    use super::*;

    fn base() -> SweepSpec {
        let mut spec = SweepSpec::new("serve");
        spec.seed = 0xFA5E;
        spec.dram_size = 64 << 20;
        spec.max_target_seconds = 30.0;
        spec
    }

    #[test]
    fn daemon_serves_a_session_and_shuts_down() {
        let h = start(ServeConfig::new(base())).unwrap();
        let addr = h.addr.to_string();
        let report =
            submit(&addr, "echo:32|fase@loopback|1c|rocket|s0", b"hi", 5_000).unwrap();
        assert!(report.contains("\"label\": \"echo:32|fase@loopback|1c|rocket|s0\""));
        assert!(report.contains("\"status\": \"ok\""));
        let stats = h.stats().unwrap();
        assert!(stats.contains("\"sessions_completed\": 1"), "{stats}");
        h.shutdown();
    }

    #[test]
    fn bad_atoms_come_back_as_protocol_errors() {
        let h = start(ServeConfig::new(base())).unwrap();
        let addr = h.addr.to_string();
        let err = submit(&addr, "not-a-label", &[], 1_000).unwrap_err();
        assert!(err.contains("bad"), "{err}");
        h.shutdown();
    }

    #[test]
    fn full_queue_is_busy_and_submit_retries_through_it() {
        // One slot, zero queue: a long spin session holds the slot while
        // a second submit spins on BUSY until the slot frees.
        let mut cfg = ServeConfig::new(base());
        cfg.max_sessions = 1;
        cfg.queue_cap = 0;
        let h = start(cfg).unwrap();
        let addr = h.addr.to_string();
        std::thread::scope(|s| {
            let a = s.spawn(|| submit(&addr, "spin:2000000|fullsys|1c|rocket|s0", &[], 30_000));
            let b = s.spawn(|| submit(&addr, "spin:10|fullsys|1c|rocket|s1", &[], 30_000));
            assert!(a.join().unwrap().is_ok());
            assert!(b.join().unwrap().is_ok());
        });
        h.shutdown();
    }
}
