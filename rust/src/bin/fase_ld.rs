//! fase-ld — static linker for RV64 freestanding objects.
//!
//! Usage: fase-ld --o out.elf in1.o [in2.o ...] [--base 0x10000] [--entry _start]
//!
//! This environment ships a riscv64-capable clang but no riscv linker, so
//! guest benchmarks are linked with this tool (see guest/ and the Makefile).

use fase::elfio::{link, read::Object, write::write_exec, LinkOptions};
use fase::util::cli::{parse_u64, Args};

fn main() {
    let args = Args::from_env();
    let out = match args.get("o").or_else(|| args.get("out")) {
        Some(o) => o.to_string(),
        None => {
            eprintln!("usage: fase-ld --o out.elf in1.o [in2.o ...] [--base ADDR] [--entry SYM]");
            std::process::exit(2);
        }
    };
    let inputs: Vec<&String> = args.positional().iter().collect();
    if inputs.is_empty() {
        eprintln!("fase-ld: no input objects");
        std::process::exit(2);
    }
    let mut opts = LinkOptions::default();
    if let Some(b) = args.get("base") {
        opts.base = parse_u64(b).unwrap_or_else(|| {
            eprintln!("fase-ld: bad --base {b:?}");
            std::process::exit(2);
        });
    }
    opts.entry_symbol = args.str_or("entry", "_start");

    let mut objects = Vec::new();
    for path in &inputs {
        match Object::load(std::path::Path::new(path.as_str())) {
            Ok(o) => objects.push(o),
            Err(e) => {
                eprintln!("fase-ld: {path}: {e}");
                std::process::exit(1);
            }
        }
    }
    match link(&objects, &opts) {
        Ok(img) => {
            let bytes = write_exec(&img);
            if let Err(e) = std::fs::write(&out, bytes) {
                eprintln!("fase-ld: writing {out}: {e}");
                std::process::exit(1);
            }
            eprintln!(
                "fase-ld: {} <- {} object(s), entry {:#x}, text {} bytes",
                out,
                objects.len(),
                img.entry,
                img.sections[0].memsz
            );
        }
        Err(e) => {
            eprintln!("fase-ld: {e}");
            std::process::exit(1);
        }
    }
}
