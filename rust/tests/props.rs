//! Property-based tests on coordinator invariants (scheduler routing,
//! futex queues, VM state/device-page-table coherence, controller batching
//! equivalence, machine determinism) using the in-repo propcheck harness.
//! Widen with FASE_PROP_CASES=512, replay with FASE_PROP_SEED=<seed>.

use fase::coordinator::sched::{Scheduler, TState, ThreadCtx};
use fase::coordinator::target::{DirectTarget, KernelCosts, TargetOps};
use fase::coordinator::vm::{AddressSpace, PageAlloc, PAGE, PROT_READ, PROT_WRITE};
use fase::fase::controller::Controller;
use fase::fase::htp::{HfOp, Req, Resp};
use fase::fase::transport::BatchFrame;
use fase::rv64::decode::encode;
use fase::soc::machine::DRAM_BASE;
use fase::soc::{Machine, MachineConfig};
use fase::util::json::{parse, Json};
use fase::util::propcheck::quick;
use fase::util::prng::Prng;

fn direct(n: usize, mb: u64) -> DirectTarget {
    let m = Machine::new(MachineConfig { n_harts: n, dram_size: mb << 20, ..Default::default() });
    let mut t = DirectTarget::new(m, KernelCosts::default());
    t.timer_enabled = false;
    t
}

/// Scheduler: after any sequence of spawn/dispatch/block/wake/exit
/// operations, (a) no tid occupies two CPUs, (b) ready and running are
/// disjoint, (c) every alive thread is in exactly one place.
#[test]
fn prop_scheduler_state_machine() {
    quick("scheduler state machine", |rng: &mut Prng| {
        let n_cpus = 1 + rng.below(4) as usize;
        let mut t = direct(n_cpus, 8);
        let mut s = Scheduler::new(n_cpus);
        for _ in 0..1 + rng.below(4) {
            s.spawn(ThreadCtx::zeroed());
        }
        for _step in 0..200 {
            match rng.below(6) {
                0 => {
                    if s.tcbs.len() < 12 {
                        s.spawn(ThreadCtx::zeroed());
                    }
                }
                1 => {
                    s.fill_idle_cpus(&mut t, 0);
                }
                2 => {
                    // block a running thread on a random futex
                    let cpu = rng.below(n_cpus as u64) as usize;
                    if s.current(cpu).is_some() {
                        s.save_context(&mut t, cpu, 0x1000);
                        let pa = 0x100 * (1 + rng.below(4));
                        s.block_current(cpu, TState::FutexWait { pa, va: pa });
                    }
                }
                3 => {
                    let pa = 0x100 * (1 + rng.below(4));
                    s.futex_wake(pa, 1 + rng.below(3) as usize);
                }
                4 => {
                    let cpu = rng.below(n_cpus as u64) as usize;
                    if s.current(cpu).is_some() {
                        s.exit_current(cpu);
                    }
                }
                _ => {
                    let cpu = rng.below(n_cpus as u64) as usize;
                    if s.current(cpu).is_some() {
                        s.save_context(&mut t, cpu, 0x2000);
                        let until = 1000 + rng.below(1000);
                        s.block_current(cpu, TState::Sleep { until });
                    }
                    s.expire_sleepers(3000);
                }
            }
            // ---- invariants ----
            let mut seen = std::collections::HashSet::new();
            for cpu in 0..n_cpus {
                if let Some(tid) = s.current(cpu) {
                    if !seen.insert(tid) {
                        return Err(format!("tid {tid} on two cpus"));
                    }
                    if s.tcb(tid).state != TState::Running(cpu) {
                        return Err(format!("tid {tid} running[{cpu}] but state {:?}", s.tcb(tid).state));
                    }
                }
            }
            for &tid in &s.ready {
                if seen.contains(&tid) {
                    return Err(format!("tid {tid} both ready and running"));
                }
                if s.tcb(tid).state != TState::Ready {
                    return Err(format!("ready tid {tid} state {:?}", s.tcb(tid).state));
                }
            }
            // every alive thread is accounted for exactly once
            for (tid, tcb) in &s.tcbs {
                let places = [
                    matches!(tcb.state, TState::Running(_)) as u32,
                    s.ready.contains(tid) as u32,
                    s.futex_q.values().any(|q| q.contains(tid)) as u32,
                    matches!(tcb.state, TState::Sleep { .. }) as u32,
                    matches!(tcb.state, TState::Exited) as u32,
                ];
                if places.iter().sum::<u32>() != 1 {
                    return Err(format!("tid {tid} in {places:?} places (state {:?})", tcb.state));
                }
            }
        }
        Ok(())
    });
}

/// VM: after random mmap/fault/munmap sequences, the software mirror and
/// the on-device SV39 page table agree for every address, and refcounts
/// stay consistent.
#[test]
fn prop_vm_mirror_matches_device_page_table() {
    quick("vm mirror == device PT", |rng: &mut Prng| {
        let mut t = direct(1, 64);
        let base_ppn = (DRAM_BASE + (1 << 20)) >> 12;
        let end_ppn = (DRAM_BASE + (64 << 20)) >> 12;
        let mut alloc = PageAlloc::new(base_ppn, end_ppn);
        let mut vm = AddressSpace::new(&mut t, 0, &mut alloc).map_err(|e| e.to_string())?;
        vm.preload = rng.below(8);
        let mut regions: Vec<(u64, u64)> = Vec::new();
        for _ in 0..40 {
            match rng.below(4) {
                0 => {
                    let pages = 1 + rng.below(8);
                    let va = vm.mmap_anon(pages * PAGE, PROT_READ | PROT_WRITE);
                    regions.push((va, pages * PAGE));
                }
                1 => {
                    if let Some(&(va, len)) = regions.last() {
                        let off = rng.below(len / PAGE) * PAGE;
                        vm.handle_fault(&mut t, 0, &mut alloc, va + off, rng.bool())
                            .map_err(|e| e.to_string())?;
                    }
                }
                2 => {
                    if !regions.is_empty() {
                        let i = rng.below(regions.len() as u64) as usize;
                        let (va, len) = regions.swap_remove(i);
                        vm.munmap(&mut t, 0, &mut alloc, va, len);
                    }
                }
                _ => {
                    if let Some(&(va, len)) = regions.first() {
                        let data = [rng.next_u64() as u8; 24];
                        let off = rng.below(len.saturating_sub(32).max(1));
                        vm.write_guest(&mut t, 0, &mut alloc, va + off, &data)
                            .map_err(|e| e.to_string())?;
                    }
                }
            }
        }
        // Walk the DEVICE page table for every mirror entry and compare.
        for (&vpn, info) in vm.pages.iter() {
            let va = vpn << 12;
            let root = vm.root_ppn << 12;
            let l2e = t.mem_r(0, root + ((va >> 30) & 0x1ff) * 8);
            if l2e & 1 == 0 {
                return Err(format!("va {va:#x}: L2 entry invalid"));
            }
            let l1 = (l2e >> 10) << 12;
            let l1e = t.mem_r(0, l1 + ((va >> 21) & 0x1ff) * 8);
            if l1e & 1 == 0 {
                return Err(format!("va {va:#x}: L1 entry invalid"));
            }
            let l0 = (l1e >> 10) << 12;
            let l0e = t.mem_r(0, l0 + ((va >> 12) & 0x1ff) * 8);
            if l0e & 1 == 0 {
                return Err(format!("va {va:#x}: leaf invalid but mirrored"));
            }
            let dev_ppn = l0e >> 10;
            if dev_ppn != info.ppn {
                return Err(format!("va {va:#x}: mirror ppn {:#x} != device {dev_ppn:#x}", info.ppn));
            }
            if alloc.refcount(info.ppn) == 0 {
                return Err(format!("va {va:#x}: mapped page has refcount 0"));
            }
        }
        // Segments never overlap.
        let mut segs: Vec<(u64, u64)> = vm.segments.iter().map(|s| (s.start, s.end)).collect();
        segs.sort();
        for w in segs.windows(2) {
            if w[0].1 > w[1].0 {
                return Err(format!("overlapping segments {w:?}"));
            }
        }
        Ok(())
    });
}

/// Controller page operations are byte-equivalent to direct physical
/// memory manipulation, and always restore staged registers.
#[test]
fn prop_controller_page_ops_equivalence() {
    quick("controller page ops == direct writes", |rng: &mut Prng| {
        let mut m = Machine::new(MachineConfig { n_harts: 1, dram_size: 16 << 20, ..Default::default() });
        let mut c = Controller::new(1, true, 8);
        // random pre-existing register state must survive
        let regs: Vec<u64> = (0..32).map(|_| rng.next_u64()).collect();
        for i in 1..32 {
            use fase::iface::CpuInterface;
            m.reg_write(0, i as u8, regs[i]);
        }
        let ppn_a = (DRAM_BASE >> 12) + 100 + rng.below(50);
        let ppn_b = ppn_a + 60 + rng.below(50);
        let val = rng.next_u64();
        c.execute(&mut m, &Req::PageS { cpu: 0, ppn: ppn_a, val });
        for off in [0u64, 8, 2048, 4088] {
            let got = m.ms.phys.read_u64((ppn_a << 12) + off).unwrap();
            if got != val {
                return Err(format!("PageS: off {off}: {got:#x} != {val:#x}"));
            }
        }
        c.execute(&mut m, &Req::PageCp { cpu: 0, src_ppn: ppn_a, dst_ppn: ppn_b });
        let a = m.ms.phys.slice(ppn_a << 12, 4096).unwrap().to_vec();
        let b = m.ms.phys.slice(ppn_b << 12, 4096).unwrap().to_vec();
        if a != b {
            return Err("PageCp mismatch".into());
        }
        // PageR equals direct read
        let (resp, _) = c.execute(&mut m, &Req::PageR { cpu: 0, ppn: ppn_b });
        match resp {
            fase::fase::htp::Resp::Page(p) => {
                if p.as_slice() != b.as_slice() {
                    return Err("PageR mismatch".into());
                }
            }
            other => return Err(format!("PageR: {other:?}")),
        }
        // staged registers restored
        use fase::iface::CpuInterface;
        for i in 1..32 {
            let got = m.reg_read(0, i as u8);
            if got != regs[i as usize] {
                return Err(format!("reg x{i} clobbered: {got:#x} != {:#x}", regs[i as usize]));
            }
        }
        Ok(())
    });
}

/// The machine is deterministic: identical programs produce identical
/// tick/instret/register outcomes.
#[test]
fn prop_machine_determinism() {
    quick("machine determinism", |rng: &mut Prng| {
        let words: Vec<u32> = (0..20)
            .map(|_| match rng.below(4) {
                0 => encode::addi(5, 5, (rng.below(100) as i32) - 50),
                1 => encode::slli(6, 5, (rng.below(16)) as u32),
                2 => encode::or(7, 5, 6),
                _ => encode::addi(8, 7, 1),
            })
            .chain(std::iter::once(encode::self_loop()))
            .collect();
        let run = |words: &[u32]| {
            let mut m = Machine::new(MachineConfig { n_harts: 1, dram_size: 4 << 20, ..Default::default() });
            for (i, w) in words.iter().enumerate() {
                m.ms.phys.write_n(DRAM_BASE + 0x100 + 4 * i as u64, 4, *w as u64);
            }
            m.harts[0].pc = DRAM_BASE + 0x100;
            m.harts[0].stop_fetch = false;
            m.run_until(50_000);
            (m.harts[0].time, m.harts[0].instret, m.harts[0].regs)
        };
        let a = run(&words);
        let b = run(&words);
        if a != b {
            return Err("non-deterministic machine state".into());
        }
        Ok(())
    });
}

/// Futex wake ordering is FIFO and wake counts are exact.
#[test]
fn prop_futex_fifo_exact_counts() {
    quick("futex FIFO + exact wake counts", |rng: &mut Prng| {
        let mut s = Scheduler::new(8);
        let n = 2 + rng.below(6) as usize;
        let mut order = Vec::new();
        for i in 0..n {
            let tid = s.spawn(ThreadCtx::zeroed());
            s.ready.pop_back();
            s.running[i] = Some(tid);
            s.tcbs.get_mut(&tid).unwrap().state = TState::Running(i);
            s.block_current(i, TState::FutexWait { pa: 0x500, va: 0x500 });
            order.push(tid);
        }
        let k = 1 + rng.below(n as u64) as usize;
        let woken = s.futex_wake(0x500, k);
        if woken.len() != k.min(n) {
            return Err(format!("woke {} expected {}", woken.len(), k.min(n)));
        }
        if woken != order[..k.min(n)] {
            return Err(format!("order {woken:?} != {:?}", &order[..k.min(n)]));
        }
        let rest = s.futex_wake(0x500, usize::MAX >> 1);
        if rest.len() != n - k.min(n) {
            return Err("remaining wake count wrong".into());
        }
        Ok(())
    });
}

// ---- HTP wire-format properties ----

/// A random batchable request addressed to `cpu` (everything except the
/// global `Next`/`Tick`, which never ride batch frames).
fn arb_req(rng: &mut Prng, cpu: u8) -> Req {
    match rng.below(12) {
        0 => Req::Redirect { cpu, pc: rng.next_u64(), switch: rng.bool() },
        1 => Req::SetMmu { cpu, satp: rng.next_u64() },
        2 => Req::FlushTlb { cpu },
        3 => Req::SyncI { cpu },
        4 => {
            let op = match rng.below(3) {
                0 => HfOp::Add,
                1 => HfOp::ClearAddr,
                _ => HfOp::ClearAll,
            };
            Req::HFutex { cpu, op, addr: rng.next_u64() }
        }
        5 => Req::RegR { cpu, idx: rng.below(64) as u8 },
        6 => Req::RegW { cpu, idx: rng.below(64) as u8, val: rng.next_u64() },
        7 => Req::MemR { cpu, addr: rng.next_u64() },
        8 => Req::MemW { cpu, addr: rng.next_u64(), val: rng.next_u64() },
        9 => Req::PageS { cpu, ppn: rng.next_u64() >> 12, val: rng.next_u64() },
        10 => Req::PageCp {
            cpu,
            src_ppn: rng.next_u64() >> 12,
            dst_ppn: rng.next_u64() >> 12,
        },
        _ => {
            let mut data = Box::new([0u8; 4096]);
            for b in data.iter_mut() {
                *b = rng.next_u64() as u8;
            }
            Req::PageW { cpu, ppn: rng.next_u64() >> 12, data }
        }
    }
}

fn arb_resp(rng: &mut Prng) -> Resp {
    match rng.below(5) {
        0 => Resp::Ok,
        1 => Resp::Word(rng.next_u64()),
        2 => Resp::Exception {
            cpu: rng.below(8) as u8,
            cause: rng.below(16),
            epc: rng.next_u64(),
            tval: rng.next_u64(),
            nr: rng.below(512),
            at: rng.next_u64(),
        },
        3 => {
            let mut page = Box::new([0u8; 4096]);
            for b in page.iter_mut() {
                *b = rng.next_u64() as u8;
            }
            Resp::Page(page)
        }
        _ => Resp::Fault(rng.below(16) as u8),
    }
}

/// Every request and response encodes to exactly `wire_len` bytes and
/// decodes back to itself (including `Next`/`Tick`, via singleton frames).
#[test]
fn prop_htp_codec_roundtrip() {
    quick("HTP codec roundtrip", |rng: &mut Prng| {
        let cpu = rng.below(8) as u8;
        let req = match rng.below(8) {
            0 => Req::Next,
            1 => Req::Tick,
            2 => Req::UTick { cpu },
            3 => Req::Interrupt { cpu },
            _ => arb_req(rng, cpu),
        };
        let e = req.encode();
        if e.len() as u64 != req.wire_len() {
            return Err(format!("{req:?}: encoded {} != wire_len {}", e.len(), req.wire_len()));
        }
        match Req::decode(&e) {
            Some((back, n)) if back == req && n == e.len() => {}
            other => return Err(format!("req decode mismatch: {other:?} vs {req:?}")),
        }
        let resp = arb_resp(rng);
        let e = resp.encode();
        if e.len() as u64 != resp.wire_len() {
            return Err(format!("{resp:?}: encoded {} != wire_len {}", e.len(), resp.wire_len()));
        }
        match Resp::decode(&e) {
            Some((back, n)) if back == resp && n == e.len() => {}
            other => return Err(format!("resp decode mismatch: {other:?} vs {resp:?}")),
        }
        Ok(())
    });
}

/// Batch frames (request and response direction) round-trip through the
/// codec, and the encoded size matches the arithmetic the channel-timing
/// layer uses.
#[test]
fn prop_batch_frame_roundtrip() {
    quick("batch frame roundtrip", |rng: &mut Prng| {
        let cpu = rng.below(8) as u8;
        let n = 1 + rng.below(12) as usize;
        let frame = BatchFrame::new(cpu, (0..n).map(|_| arb_req(rng, cpu)).collect());
        let e = frame.encode();
        if e.len() as u64 != frame.wire_len() {
            return Err(format!("frame encoded {} != wire_len {}", e.len(), frame.wire_len()));
        }
        match BatchFrame::decode(&e) {
            Some((back, used)) if back == frame && used == e.len() => {}
            _ => return Err(format!("frame decode mismatch (n={n})")),
        }
        let resps: Vec<Resp> = (0..n).map(|_| arb_resp(rng)).collect();
        let er = BatchFrame::encode_resps(&resps);
        if er.len() as u64 != BatchFrame::resp_wire_len(&resps) {
            return Err("resp stream length mismatch".into());
        }
        match BatchFrame::decode_resps(&er, n) {
            Some((back, used)) if back == resps && used == er.len() => Ok(()),
            _ => Err(format!("resp stream decode mismatch (n={n})")),
        }
    });
}

/// The batching layer never inflates traffic: a frame's wire bytes (both
/// directions) are at most the sum of its individually-framed requests.
#[test]
fn prop_batch_wire_bytes_leq_individual() {
    quick("batched bytes <= individual bytes", |rng: &mut Prng| {
        let cpu = rng.below(8) as u8;
        let n = 1 + rng.below(16) as usize;
        let frame = BatchFrame::new(cpu, (0..n).map(|_| arb_req(rng, cpu)).collect());
        let resps: Vec<Resp> = (0..n).map(|_| arb_resp(rng)).collect();
        let individual_req: u64 = frame.reqs.iter().map(|r| r.wire_len()).sum();
        let individual_resp: u64 = resps.iter().map(|r| r.wire_len()).sum();
        let framed = frame.wire_len() + BatchFrame::resp_wire_len(&resps);
        if framed > individual_req + individual_resp {
            return Err(format!(
                "n={n}: framed {framed} > individual {}",
                individual_req + individual_resp
            ));
        }
        if frame.saved_bytes() != individual_req + individual_resp - framed {
            return Err("saved_bytes disagrees with direct computation".into());
        }
        Ok(())
    });
}

// ---- JSON tree properties (util/json.rs) ----

/// An escape-heavy string: quotes, backslashes, control characters,
/// multi-byte and non-BMP code points, mixed with plain ASCII.
fn arb_string(rng: &mut Prng) -> String {
    let mut s = String::new();
    for _ in 0..rng.below(16) {
        match rng.below(10) {
            0 => s.push('"'),
            1 => s.push('\\'),
            2 => s.push('\n'),
            3 => s.push('\t'),
            // Control characters the writer must \u-escape (NUL included).
            4 => s.push(char::from_u32(rng.below(0x20) as u32).unwrap()),
            5 => s.push('é'),
            6 => s.push('\u{1F600}'),
            7 => s.push('/'),
            _ => s.push((b'a' + rng.below(26) as u8) as char),
        }
    }
    s
}

/// A finite float that is never negative zero (Display prints "-0" but
/// the parser normalizes it to Int(0), so -0.0 is not text-stable and
/// this crate never emits it).
fn arb_float(rng: &mut Prng) -> f64 {
    (rng.next_u64() as i32 as f64) / (1u64 << rng.below(20)) as f64
}

fn arb_json(rng: &mut Prng, depth: u64) -> Json {
    let pick = if depth == 0 { rng.below(6) } else { rng.below(8) };
    match pick {
        0 => Json::Null,
        1 => Json::Bool(rng.bool()),
        2 => Json::Int(rng.next_u64() as i64),
        3 => Json::u64(rng.next_u64()),
        4 => Json::f64(arb_float(rng)),
        5 => Json::Str(arb_string(rng)),
        6 => Json::Arr((0..rng.below(5)).map(|_| arb_json(rng, depth - 1)).collect()),
        _ => Json::Obj(
            (0..rng.below(5))
                .map(|i| (format!("k{i}_{}", arb_string(rng)), arb_json(rng, depth - 1)))
                .collect(),
        ),
    }
}

/// Serialize -> parse -> re-serialize is a textual fixed point for any
/// tree, and the parsed tree is a fixed point of parse itself. (The
/// trees may differ once: Display prints Float(2.0) as "2", which parses
/// back as Int(2) — but the *text* never changes, which is what the
/// byte-identical determinism gates rely on.)
#[test]
fn prop_json_roundtrip_is_textual_fixed_point() {
    quick("json textual fixed point", |rng: &mut Prng| {
        let j = arb_json(rng, 4);
        let text1 = j.to_string_pretty();
        let back = parse(&text1).map_err(|e| format!("{e}\n{text1}"))?;
        let text2 = back.to_string_pretty();
        if text1 != text2 {
            return Err(format!("text changed across a parse:\n{text1}\nvs\n{text2}"));
        }
        let again = parse(&text2).map_err(|e| e.to_string())?;
        if again != back {
            return Err("parse is not a fixed point".into());
        }
        Ok(())
    });
}

/// Strings survive the writer's escaping and the parser's unescaping
/// exactly, for any mix of quotes, backslashes, control characters and
/// multi-byte code points.
#[test]
fn prop_json_escape_heavy_strings_roundtrip() {
    quick("json string escapes", |rng: &mut Prng| {
        let s = arb_string(rng);
        let j = Json::Str(s.clone());
        match parse(&j.to_string_pretty()) {
            Ok(Json::Str(back)) if back == s => Ok(()),
            Ok(other) => Err(format!("{s:?} came back as {other:?}")),
            Err(e) => Err(format!("{s:?}: {e}")),
        }
    });
}

/// Deeply nested arrays round-trip (the parser recurses per level; the
/// report never nests this far, so this is pure headroom).
#[test]
fn prop_json_deep_arrays_roundtrip() {
    quick("json deep arrays", |rng: &mut Prng| {
        let depth = 1 + rng.below(150);
        let mut j = Json::Int(rng.next_u64() as i64);
        for _ in 0..depth {
            j = Json::Arr(vec![j]);
        }
        let text = j.to_string_pretty();
        match parse(&text) {
            Ok(back) if back == j => Ok(()),
            Ok(_) => Err(format!("depth {depth}: tree changed")),
            Err(e) => Err(format!("depth {depth}: {e}")),
        }
    });
}

/// Numeric variants keep their identity through a text round-trip: any
/// i64 stays Int, any u64 above i64::MAX stays UInt, and floats with a
/// fractional part stay Float with the exact same bits.
#[test]
fn prop_json_number_identity() {
    quick("json number identity", |rng: &mut Prng| {
        let i = rng.next_u64() as i64;
        if parse(&Json::Int(i).to_string_pretty()).ok() != Some(Json::Int(i)) {
            return Err(format!("i64 {i} did not survive"));
        }
        let u = (1u64 << 63) | rng.next_u64();
        if parse(&Json::UInt(u).to_string_pretty()).ok() != Some(Json::UInt(u)) {
            return Err(format!("u64 {u} did not survive"));
        }
        // odd / 2^k is always fractional, so Display keeps a '.' and the
        // parser keeps it a Float.
        let f = (rng.next_u64() as i32 | 1) as f64 / (1u64 << (1 + rng.below(8))) as f64;
        match parse(&Json::Float(f).to_string_pretty()) {
            Ok(Json::Float(back)) if back.to_bits() == f.to_bits() => Ok(()),
            other => Err(format!("float {f} came back as {other:?}")),
        }
    });
}

/// PageAlloc never double-allocates and refcounting round-trips.
#[test]
fn prop_page_alloc_unique_and_refcounted() {
    quick("page alloc uniqueness", |rng: &mut Prng| {
        let mut a = PageAlloc::new(1000, 1200);
        let mut live: Vec<u64> = Vec::new();
        for _ in 0..300 {
            match rng.below(3) {
                0 => {
                    if let Ok(p) = a.alloc() {
                        if live.contains(&p) {
                            return Err(format!("double alloc of {p}"));
                        }
                        live.push(p);
                    }
                }
                1 => {
                    if !live.is_empty() {
                        let i = rng.below(live.len() as u64) as usize;
                        a.incref(live[i]);
                        a.decref(live[i]);
                    }
                }
                _ => {
                    if !live.is_empty() {
                        let i = rng.below(live.len() as u64) as usize;
                        let p = live.swap_remove(i);
                        if !a.decref(p) {
                            return Err(format!("page {p} not freed at refcount 0"));
                        }
                    }
                }
            }
        }
        Ok(())
    });
}
