//! Serve-layer integration: session isolation, packing invariance and
//! board-pool behavior through the real TCP daemon (docs/serve.md).
//!
//! The determinism contract under test: a session's report bytes are a
//! pure function of (daemon base spec, session atom, stdin) — identical
//! whether the session runs solo, packed 8-deep on one board, or spread
//! across four boards.

use fase::serve::{start, submit, ServeConfig};
use fase::sweep::SweepSpec;

fn base() -> SweepSpec {
    let mut spec = SweepSpec::new("serve");
    spec.seed = 0xFA5E;
    spec.dram_size = 64 << 20;
    spec.max_target_seconds = 30.0;
    spec
}

fn cfg(boards: usize, max_sessions: usize) -> ServeConfig {
    let mut c = ServeConfig::new(base());
    c.boards = boards;
    c.max_sessions = max_sessions;
    c.queue_cap = 16;
    c
}

fn atom(i: usize) -> String {
    format!("echo:64|fase@uart:921600|1c|rocket|s{i}")
}

fn payload(i: usize) -> Vec<u8> {
    format!("session {i}: {}", "x".repeat(i + 1)).into_bytes()
}

/// Run the 8 echo sessions against a fresh daemon, one at a time.
fn run_serially(boards: usize) -> Vec<String> {
    let h = start(cfg(boards, 1)).unwrap();
    let addr = h.addr.to_string();
    let reports =
        (0..8).map(|i| submit(&addr, &atom(i), &payload(i), 60_000).unwrap()).collect();
    h.shutdown();
    reports
}

/// Run the 8 echo sessions against a fresh daemon, all at once.
fn run_concurrently(boards: usize) -> Vec<String> {
    let h = start(cfg(boards, 8)).unwrap();
    let addr = h.addr.to_string();
    let mut reports = vec![String::new(); 8];
    std::thread::scope(|s| {
        let handles: Vec<_> = (0..8)
            .map(|i| {
                let addr = addr.clone();
                s.spawn(move || submit(&addr, &atom(i), &payload(i), 60_000).unwrap())
            })
            .collect();
        for (i, t) in handles.into_iter().enumerate() {
            reports[i] = t.join().unwrap();
        }
    });
    h.shutdown();
    reports
}

#[test]
fn per_session_reports_are_byte_identical_solo_packed_and_multiboard() {
    let solo = run_serially(1);
    let packed = run_concurrently(1);
    let spread = run_concurrently(4);
    for i in 0..8 {
        assert!(solo[i].contains(&format!("\"label\": \"{}\"", atom(i))));
        assert!(solo[i].contains("\"status\": \"ok\""), "{}", solo[i]);
        assert_eq!(solo[i], packed[i], "session {i}: solo vs 8-way on 1 board");
        assert_eq!(solo[i], spread[i], "session {i}: solo vs 8-way on 4 boards");
    }
    // Distinct stdin payloads and seeds: no two sessions report alike.
    for i in 1..8 {
        assert_ne!(solo[0], solo[i], "sessions must be isolated, not copies");
    }
}

#[test]
fn board_stats_report_cross_session_coalescing() {
    // Four syscall-storm sessions on one board: their frame tapes overlap
    // heavily in the replay, so the daemon's STATS must show merged
    // frames and a strictly sub-serial board makespan.
    let h = start(cfg(1, 4)).unwrap();
    let addr = h.addr.to_string();
    for i in 0..4 {
        let report =
            submit(&addr, &format!("storm:64|fase@uart:921600|1c|rocket|s{i}"), &[], 60_000)
                .unwrap();
        assert!(report.contains("\"status\": \"ok\""), "{report}");
    }
    let stats = h.stats().unwrap();
    let doc = fase::util::json::parse(&stats).unwrap();
    assert_eq!(doc.get("sessions_completed").and_then(|v| v.as_f64()), Some(4.0));
    let boards = doc.get("boards").unwrap().as_arr().unwrap();
    assert_eq!(boards.len(), 1);
    let b = &boards[0];
    let num = |k: &str| b.get(k).and_then(|v| v.as_f64()).unwrap();
    assert_eq!(num("sessions"), 4.0);
    assert!(num("frames") > 0.0);
    assert!(num("merged_frames") > 0.0, "storm x4 on one board must coalesce: {stats}");
    assert!(
        num("board_ticks") < num("serial_ticks"),
        "coalescing must strictly beat the serial replay: {stats}"
    );
    h.shutdown();
}
