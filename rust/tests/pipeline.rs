//! Pipelined-HTP integration tests (docs/htp-wire.md §5, DESIGN.md
//! §Transport).
//!
//! Depth 1 must keep the legacy serial protocol byte-for-byte — no tag
//! overhead, no `pipeline` report member. Deeper windows trade a few tag
//! bytes for hidden wire time, so the storm workload's channel stall must
//! fall *strictly* as the window deepens, while the architectural surface
//! (retired instructions, user ticks) holds still.

use fase::coordinator::runtime::{run_exe, Mode, RunConfig, RunResult};
use fase::coordinator::target::HostLatency;
use fase::fase::transport::TransportSpec;
use fase::sweep::SynthKind;

fn storm_at(transport: TransportSpec, outstanding: u32) -> RunResult {
    let cfg = RunConfig {
        mode: Mode::Fase { transport, hfutex: true, latency: HostLatency::default() },
        dram_size: 64 << 20,
        max_target_seconds: 60.0,
        outstanding,
        ..Default::default()
    };
    let exe = fase::sweep::synth::build(SynthKind::Storm { calls: 24 });
    let r = run_exe(cfg, &exe, &["storm:24".to_string()], &[]);
    assert_eq!(r.error, None, "o{outstanding}: {:?}", r.error);
    assert_eq!(r.exit_code, 0, "o{outstanding}");
    r
}

#[test]
fn depth_one_is_the_legacy_serial_protocol() {
    let r = storm_at(TransportSpec::uart(921_600), 1);
    // No tagged framing, no hidden time, no credit machinery at depth 1.
    assert_eq!(r.pipeline.depth, 1);
    assert_eq!(r.pipeline.tagged_frames, 0);
    assert_eq!(r.pipeline.tag_bytes, 0);
    assert_eq!(r.pipeline.hidden_ticks, 0);
    assert_eq!(r.pipeline.spec_pushes, 0);
    // ... and the report keeps the pre-pipelining shape: no `pipeline`
    // member (the CI invisibility gate diffs whole report files on this).
    let json = r.metrics_json(None).to_string_pretty();
    assert!(!json.contains("\"pipeline\""), "depth-1 report grew a pipeline member:\n{json}");
}

#[test]
fn channel_stall_strictly_decreases_with_depth() {
    let runs: Vec<RunResult> =
        [1u32, 2, 4].iter().map(|&d| storm_at(TransportSpec::uart(921_600), d)).collect();
    let stalls: Vec<u64> = runs.iter().map(|r| r.stall.channel_ticks).collect();
    assert!(
        stalls[0] > stalls[1] && stalls[1] > stalls[2],
        "channel stall must fall strictly with depth 1 -> 2 -> 4: {stalls:?}"
    );
    // Total target time follows the stall down.
    assert!(runs[0].ticks > runs[2].ticks, "{} !> {}", runs[0].ticks, runs[2].ticks);
    // Deeper windows hide more wire time and carry real tag overhead.
    assert!(runs[1].pipeline.hidden_ticks > 0);
    assert!(runs[2].pipeline.hidden_ticks >= runs[1].pipeline.hidden_ticks);
    assert!(runs[1].pipeline.tagged_frames > 0);
    assert!(runs[1].pipeline.tag_bytes > 0);
    // Pipelining moves stall, never the architectural surface.
    for r in &runs[1..] {
        assert_eq!(r.instret, runs[0].instret, "retired count moved at depth {}", r.pipeline.depth);
        assert_eq!(r.uticks, runs[0].uticks, "user ticks moved at depth {}", r.pipeline.depth);
    }
    // The report grows a `pipeline` member only once the window opens.
    let json = runs[2].metrics_json(None).to_string_pretty();
    assert!(json.contains("\"pipeline\""), "depth-4 report lacks the pipeline member:\n{json}");
    assert!(json.contains("\"depth\": 4"), "{json}");
}

#[test]
fn loopback_has_no_wire_time_to_hide() {
    // Loopback transfers cost zero channel ticks, so there is no wire
    // time to overlap: the skid buffer banks nothing and nothing hides.
    // Speculative argument pushes may still spare whole frames (and their
    // per-request host latency), so target time can only improve.
    let serial = storm_at(TransportSpec::Loopback, 1);
    let piped = storm_at(TransportSpec::Loopback, 4);
    assert_eq!(serial.stall.channel_ticks, 0);
    assert_eq!(piped.stall.channel_ticks, 0);
    assert_eq!(piped.pipeline.hidden_ticks, 0);
    assert_eq!(serial.instret, piped.instret);
    assert!(piped.ticks <= serial.ticks, "{} > {}", piped.ticks, serial.ticks);
}
