//! Sweep orchestrator integration tests: the determinism property the CI
//! gate relies on (same spec + seed -> byte-identical JSON at any worker
//! count), JSON round-tripping against the hand-rolled parser, and the
//! perf-regression comparator end to end.

use fase::sweep::{builtin, check_against, run_sweep, Arm, SweepSpec, SynthKind, WorkloadSpec};
use fase::util::json::parse;

/// A miniature ci-smoke-shaped spec that keeps debug-mode test time low
/// while still covering all three synthetic workloads, both engines'
/// fast-path arms and both hart counts.
fn small_spec() -> SweepSpec {
    let mut spec = SweepSpec::new("test-sweep");
    spec.seed = 0xFA5E;
    spec.dram_size = 64 << 20;
    spec.max_target_seconds = 60.0;
    spec.workloads = vec![
        WorkloadSpec::synth(SynthKind::Spin { iters: 400 }),
        WorkloadSpec::synth(SynthKind::Storm { calls: 16 }),
        WorkloadSpec::synth(SynthKind::MemTouch { pages: 16 }),
    ];
    spec.arms = vec![
        Arm::Fase {
            transport: fase::fase::transport::TransportSpec::Loopback,
            hfutex: true,
            ideal_latency: false,
        },
        Arm::fase_uart(921_600),
        Arm::FullSys,
    ];
    spec.harts = vec![1, 4];
    spec
}

#[test]
fn reports_are_byte_identical_across_worker_counts() {
    let spec = small_spec();
    let a = run_sweep(&spec, 1, None, false).to_json().to_string_pretty();
    let b = run_sweep(&spec, 8, None, false).to_json().to_string_pretty();
    assert!(!a.is_empty());
    assert_eq!(a, b, "--jobs 1 and --jobs 8 must produce identical reports");
    // And re-running the same spec reproduces the same bytes again.
    let c = run_sweep(&spec, 3, None, false).to_json().to_string_pretty();
    assert_eq!(a, c);
}

#[test]
fn filtered_sweep_matches_the_full_run_cell_for_cell() {
    let spec = small_spec();
    let full = run_sweep(&spec, 4, None, false);
    let filtered = run_sweep(&spec, 4, Some("storm"), false);
    assert!(!filtered.outcomes.is_empty());
    assert!(filtered.outcomes.len() < full.outcomes.len());
    for o in &filtered.outcomes {
        let same = full
            .outcomes
            .iter()
            .find(|f| f.job.label() == o.job.label())
            .expect("filtered scenario exists in full run");
        assert_eq!(o.result.ticks, same.result.ticks, "{}", o.job.label());
        assert_eq!(o.result.instret, same.result.instret);
        assert_eq!(o.result.total_bytes, same.result.total_bytes);
    }
}

#[test]
fn report_round_trips_through_the_parser() {
    let spec = small_spec();
    let doc = run_sweep(&spec, 4, Some("spin"), false).to_json();
    let text = doc.to_string_pretty();
    let back = parse(&text).expect("report parses");
    // Tree equality modulo numeric variant (Float(1.0) prints as "1" and
    // parses back Int) is covered by re-serializing: bytes must match.
    assert_eq!(back.to_string_pretty(), text);
    // Schema and structure checks a hand-written consumer would do.
    assert_eq!(back.get("schema").and_then(|v| v.as_u64()), Some(1));
    assert_eq!(back.get("sweep").and_then(|v| v.as_str()), Some("test-sweep"));
    let jobs = back.get("jobs").and_then(|v| v.as_arr()).expect("jobs array");
    assert_eq!(jobs.len(), 6, "spin workload x 3 arms x 2 hart counts");
    for j in jobs {
        assert_eq!(j.get("status").and_then(|v| v.as_str()), Some("ok"));
        assert_eq!(j.get("workload").and_then(|v| v.as_str()), Some("spin:400"));
        let metrics = j.get("metrics").expect("metrics");
        assert!(metrics.get("ticks").and_then(|v| v.as_u64()).unwrap() > 0);
        assert!(metrics.get("wall_seconds").is_none(), "wall-clock must not leak into reports");
    }
    // FASE arms get validation entries against the fullsys baseline of
    // the same (workload, harts) cell: 2 fase arms x 2 hart counts.
    let val = back.get("validation").and_then(|v| v.as_arr()).expect("validation array");
    assert_eq!(val.len(), 4);
}

#[test]
fn hand_written_baseline_gates_a_generated_report() {
    let spec = small_spec();
    let doc = run_sweep(&spec, 4, Some("spin:400|fullsys|1c"), false).to_json();
    let jobs = doc.get("jobs").and_then(|v| v.as_arr()).unwrap();
    assert_eq!(jobs.len(), 1);
    let label = jobs[0].get("label").unwrap().as_str().unwrap();
    let ticks = jobs[0].get("metrics").unwrap().get("ticks").unwrap().as_u64().unwrap();

    // A minimal hand-written baseline pinning one metric.
    let baseline_ok = format!(
        "{{\"schema\": 1, \"tolerances\": {{\"default_rel\": 0.05}},\n  \
         \"jobs\": [{{\"label\": \"{label}\", \"status\": \"ok\", \"exit_code\": 0,\n  \
         \"metrics\": {{\"ticks\": {ticks}}}}}]}}"
    );
    let gate = check_against(&doc, &parse(&baseline_ok).unwrap()).unwrap();
    assert!(gate.passed(), "{:?}", gate.breaches);
    assert_eq!(gate.compared_jobs, 1);

    // The same baseline with the metric perturbed beyond tolerance fails.
    let baseline_bad = baseline_ok.replace(&ticks.to_string(), &(ticks * 2).to_string());
    let gate = check_against(&doc, &parse(&baseline_bad).unwrap()).unwrap();
    assert!(!gate.passed());
    assert!(gate.breaches[0].contains("ticks"), "{:?}", gate.breaches);
}

#[test]
fn ci_smoke_spec_is_well_formed() {
    let spec = builtin("ci-smoke").expect("ci-smoke exists");
    let jobs = spec.expand(None);
    assert_eq!(jobs.len(), 18, "3 workloads x 3 arms x 2 hart counts");
    // Everything ci-smoke needs must be guest-free (runs on bare CI).
    for j in &jobs {
        assert!(
            matches!(j.workload.kind, fase::sweep::WorkloadKind::Synth(_)),
            "ci-smoke must not depend on cross-compiled guests: {}",
            j.label()
        );
    }
    // Labels are unique — they are the baseline join key.
    let mut labels: Vec<String> = jobs.iter().map(|j| j.label()).collect();
    labels.sort();
    labels.dedup();
    assert_eq!(labels.len(), 18);
}
