//! Prewarm invisibility tests (DESIGN.md §Analysis).
//!
//! The static-analysis prewarm pass hands statically discovered blocks to
//! the decoded-block engine ahead of execution. It must be architecturally
//! *and* cycle-invisible: identical registers, hart time, retired counts
//! and byte-identical sweep reports, with only `EngineStats` showing the
//! first-pass decode misses it removed.

use fase::analysis::AnalysisMode;
use fase::coordinator::runtime::{run_exe, Mode, RunConfig, RunResult};
use fase::coordinator::target::KernelCosts;
use fase::rv64::EngineKind;
use fase::sweep::{run_sweep, Arm, SweepSpec, SynthKind, WorkloadSpec};

/// One full-system storm run on the block engine with eager image load,
/// so the prewarm set is offered in one shot at load time.
fn storm_run(analysis: AnalysisMode) -> RunResult {
    let cfg = RunConfig {
        mode: Mode::FullSys { costs: KernelCosts::default() },
        dram_size: 64 << 20,
        max_target_seconds: 30.0,
        engine: EngineKind::Block,
        analysis,
        ..Default::default()
    };
    let exe = fase::sweep::synth::build(SynthKind::Storm { calls: 24 });
    let r = run_exe(cfg, &exe, &["storm:24".to_string()], &[]);
    assert_eq!(r.error, None, "{:?}", r.error);
    assert_eq!(r.exit_code, 0);
    r
}

#[test]
fn prewarm_is_invisible_but_removes_first_pass_decode_misses() {
    let cold = storm_run(AnalysisMode::Off);
    let warm = storm_run(AnalysisMode::Prewarm);
    // Architectural + timing surface: byte-identical.
    assert_eq!(cold.ticks, warm.ticks);
    assert_eq!(cold.instret, warm.instret);
    assert_eq!(cold.uticks, warm.uticks);
    assert_eq!(
        cold.metrics_json(None).to_string_pretty(),
        warm.metrics_json(None).to_string_pretty(),
        "prewarm must not move any reported metric"
    );
    // Host-side stats are the only thing allowed to differ.
    assert_eq!(cold.engine_stats.prewarmed, 0);
    assert!(warm.engine_stats.prewarmed > 0, "{:?}", warm.engine_stats);
    assert!(
        warm.engine_stats.blocks_built < cold.engine_stats.blocks_built,
        "prewarmed run must decode fewer blocks at runtime: cold {:?} warm {:?}",
        cold.engine_stats,
        warm.engine_stats
    );
}

/// The tests/engine.rs lockstep matrix (spin/storm/memtouch x
/// fase-loopback/fullsys x 1,2 harts = 12 scenarios), pinned to the block
/// engine, parameterized by the label-invisible analysis mode. Sweep jobs
/// load synthetic images lazily, so this also covers the fault-driven
/// prewarm drain.
fn lockstep_sweep(analysis: AnalysisMode) -> (String, Vec<u64>, u64, u64) {
    let mut spec = SweepSpec::new("lockstep");
    spec.seed = 0x5EED;
    spec.dram_size = 64 << 20;
    spec.max_target_seconds = 30.0;
    spec.workloads = vec![
        WorkloadSpec::synth(SynthKind::Spin { iters: 300 }),
        WorkloadSpec::synth(SynthKind::Storm { calls: 24 }),
        WorkloadSpec::synth(SynthKind::MemTouch { pages: 16 }),
    ];
    spec.arms = vec![
        Arm::Fase {
            transport: fase::fase::transport::TransportSpec::Loopback,
            hfutex: true,
            ideal_latency: false,
        },
        Arm::FullSys,
    ];
    spec.harts = vec![1, 2];
    spec.engine_override = Some(EngineKind::Block);
    spec.analysis = analysis;
    let out = run_sweep(&spec, 2, None, false);
    assert!(out.errors().is_empty(), "sweep errors at {analysis}: {:?}", out.errors());
    assert_eq!(out.outcomes.len(), 12);
    let retired = out.outcomes.iter().map(|o| o.result.instret).collect();
    let prewarmed = out.outcomes.iter().map(|o| o.result.engine_stats.prewarmed).sum();
    let built = out.outcomes.iter().map(|o| o.result.engine_stats.blocks_built).sum();
    (out.to_json().to_string_pretty(), retired, prewarmed, built)
}

#[test]
fn report_and_prewarm_sweeps_are_byte_identical() {
    let (report_r, retired_r, prewarmed_r, built_r) = lockstep_sweep(AnalysisMode::Report);
    let (report_p, retired_p, prewarmed_p, built_p) = lockstep_sweep(AnalysisMode::Prewarm);
    assert!(retired_r.iter().sum::<u64>() > 0, "workloads must retire instructions");
    assert_eq!(retired_r, retired_p, "retired counts must match per scenario");
    assert!(
        report_r == report_p,
        "sweep reports must be byte-identical across analysis modes"
    );
    // Under lazy image loading the prewarm set drains as pages fault in.
    assert_eq!(prewarmed_r, 0);
    assert!(prewarmed_p > 0, "prewarm mode must seed the block cache");
    assert!(
        built_p < built_r,
        "prewarm must reduce runtime block decodes ({built_p} vs {built_r})"
    );
}
