//! Integration tests over real guest ELFs (built by `make guests`).
//! Each test runs a cross-compiled RV64 binary through the full stack in
//! one or both modes and checks guest-visible semantics plus runtime
//! accounting. Tests are skipped (with a notice) if artifacts are missing.

use fase::coordinator::runtime::{run_elf, Mode, RunConfig, RunResult};
use fase::coordinator::target::{HostLatency, KernelCosts};
use fase::fase::transport::TransportSpec;
use std::path::PathBuf;

fn guest(name: &str) -> Option<PathBuf> {
    let p = PathBuf::from(env!("CARGO_MANIFEST_DIR")).join(format!("artifacts/guests/{name}.elf"));
    if p.exists() {
        Some(p)
    } else {
        eprintln!("SKIP: {} missing (run `make guests`)", p.display());
        None
    }
}

fn fase_cfg(cpus: usize) -> RunConfig {
    RunConfig {
        mode: Mode::Fase {
            transport: TransportSpec::uart(921_600),
            hfutex: true,
            latency: HostLatency::default(),
        },
        n_cpus: cpus,
        echo_stdout: false,
        max_target_seconds: 120.0,
        ..Default::default()
    }
}

fn fullsys_cfg(cpus: usize) -> RunConfig {
    RunConfig {
        mode: Mode::FullSys { costs: KernelCosts::default() },
        n_cpus: cpus,
        echo_stdout: false,
        max_target_seconds: 120.0,
        ..Default::default()
    }
}

fn run(cfg: RunConfig, elf: &PathBuf, args: &[&str], env: &[&str]) -> RunResult {
    let mut argv = vec![elf.display().to_string()];
    argv.extend(args.iter().map(|s| s.to_string()));
    let envp: Vec<String> = env.iter().map(|s| s.to_string()).collect();
    run_elf(cfg, elf, &argv, &envp)
}

#[test]
fn hello_argv_env_exit_code() {
    let Some(elf) = guest("hello") else { return };
    for cfg in [fase_cfg(1), fullsys_cfg(1)] {
        let mut c = cfg;
        c.guest_root = std::env::temp_dir();
        let res = run(c, &elf, &["alpha", "beta"], &["FASE_TEST_ENV=visible"]);
        assert_eq!(res.error, None);
        assert_eq!(res.exit_code, 42);
        assert!(res.stdout.contains("argc=3"), "{}", res.stdout);
        assert!(res.stdout.contains("argv[2]=beta"));
        assert!(res.stdout.contains("FASE_TEST_ENV=visible"));
    }
}

#[test]
fn threads_full_stack_both_modes() {
    let Some(elf) = guest("threads") else { return };
    for (label, cfg) in [("fase", fase_cfg(4)), ("fullsys", fullsys_cfg(4))] {
        let res = run(cfg, &elf, &["3"], &[]);
        assert_eq!(res.error, None, "{label}: {:?}", res.error);
        assert_eq!(res.exit_code, 0, "{label} stdout:\n{}", res.stdout);
        assert!(res.stdout.contains("threads OK"));
        assert!(res.context_switches >= 1);
        // clone must have been used for the 3 workers + pool
        let clones = res.syscall_counts.iter().find(|(n, _)| n == "clone").map(|(_, c)| *c);
        assert!(clones.unwrap_or(0) >= 3, "{label}: {:?}", res.syscall_counts);
    }
}

#[test]
fn crash_reports_guest_fault() {
    let Some(elf) = guest("crash") else { return };
    let res = run(fase_cfg(1), &elf, &[], &[]);
    let err = res.error.expect("crash must produce an error");
    assert!(err.contains("page fault") || err.contains("segmentation"), "{err}");
}

#[test]
fn deadlock_detected_not_hung() {
    let Some(elf) = guest("deadlock") else { return };
    let t0 = std::time::Instant::now();
    let res = run(fase_cfg(1), &elf, &[], &[]);
    assert!(res.error.unwrap_or_default().contains("deadlock"));
    assert!(t0.elapsed().as_secs() < 60, "deadlock detection must not hang");
}

#[test]
fn stress_syscall_surface() {
    let Some(elf) = guest("stress") else { return };
    for cfg in [fase_cfg(1), fullsys_cfg(2)] {
        let mut c = cfg;
        c.guest_root = std::env::temp_dir();
        let res = run(c, &elf, &[], &[]);
        assert_eq!(res.error, None);
        assert_eq!(res.exit_code, 0, "stdout:\n{}\nstderr:\n{}", res.stdout, res.stderr);
        assert!(res.stdout.contains("signal delivered"));
        assert!(res.stdout.contains("stress OK"));
    }
}

#[test]
fn timeout_guard_fires() {
    let Some(elf) = guest("coremark") else { return };
    let mut cfg = fullsys_cfg(1);
    cfg.max_target_seconds = 0.001; // absurdly small
    let res = run(cfg, &elf, &["1000"], &[]);
    assert!(res.error.unwrap_or_default().contains("time limit"));
}

#[test]
fn fase_and_fullsys_agree_functionally() {
    // Same guest computation must produce identical stdout content lines
    // (modulo timing numbers) in both modes — the syscall-emulation
    // correctness claim.
    let Some(elf) = guest("bfs") else { return };
    let a = run(fase_cfg(2), &elf, &["10", "2", "1"], &[]);
    let b = run(fullsys_cfg(2), &elf, &["10", "2", "1"], &[]);
    assert_eq!(a.error, None);
    assert_eq!(b.error, None);
    fn line_with<'a>(s: &'a str, p: &str) -> Option<&'a str> {
        s.lines().find(|l| l.starts_with(p))
    }
    assert_eq!(line_with(&a.stdout, "graph"), line_with(&b.stdout, "graph"));
    assert_eq!(line_with(&a.stdout, "reached"), line_with(&b.stdout, "reached"));
}

#[test]
fn hfutex_reduces_traffic_on_threads() {
    let Some(elf) = guest("threads") else { return };
    let mut on = fase_cfg(4);
    on.mode = Mode::Fase {
        transport: TransportSpec::uart(921_600),
        hfutex: true,
        latency: HostLatency::zero(),
    };
    let mut off = fase_cfg(4);
    off.mode = Mode::Fase {
        transport: TransportSpec::uart(921_600),
        hfutex: false,
        latency: HostLatency::zero(),
    };
    let r_on = run(on, &elf, &["3"], &[]);
    let r_off = run(off, &elf, &["3"], &[]);
    assert_eq!(r_on.error, None);
    assert_eq!(r_off.error, None);
    assert!(r_on.filtered_wakes > 0, "HFutex should filter mutex eager wakes");
    assert_eq!(r_off.filtered_wakes, 0);
    assert!(
        r_on.total_bytes < r_off.total_bytes,
        "HF {} vs NHF {}",
        r_on.total_bytes,
        r_off.total_bytes
    );
}

#[test]
fn transport_selection_changes_profile_not_results() {
    let Some(elf) = guest("hello") else { return };
    let run_with = |spec: TransportSpec| {
        let mut cfg = fase_cfg(1);
        cfg.mode = Mode::Fase { transport: spec, hfutex: true, latency: HostLatency::zero() };
        run(cfg, &elf, &[], &[])
    };
    let uart = run_with(TransportSpec::uart(921_600));
    let xdma = run_with(TransportSpec::Xdma);
    let loopback = run_with(TransportSpec::Loopback);
    for r in [&uart, &xdma, &loopback] {
        assert_eq!(r.error, None);
        assert_eq!(r.exit_code, 42);
    }
    assert_eq!(uart.transport, "uart:921600");
    assert_eq!(xdma.transport, "xdma");
    assert_eq!(loopback.transport, "loopback");
    // Functional results agree; timing profiles are ordered by bandwidth.
    assert_eq!(uart.stdout, xdma.stdout);
    assert_eq!(uart.stdout, loopback.stdout);
    assert!(uart.ticks > xdma.ticks, "uart {} vs xdma {}", uart.ticks, xdma.ticks);
    assert!(xdma.ticks > loopback.ticks, "xdma {} vs loopback {}", xdma.ticks, loopback.ticks);
    assert_eq!(loopback.stall.channel_ticks, 0);
}

#[test]
fn htp_batching_cuts_transactions_not_results() {
    let Some(elf) = guest("hello") else { return };
    let mut on = fase_cfg(1);
    on.htp_batching = true;
    let mut off = fase_cfg(1);
    off.htp_batching = false;
    let r_on = run(on, &elf, &[], &[]);
    let r_off = run(off, &elf, &[], &[]);
    assert_eq!(r_on.error, None);
    assert_eq!(r_off.error, None);
    assert_eq!(r_on.stdout, r_off.stdout);
    assert!(r_on.batch_frames > 0, "load + syscalls must produce batch frames");
    assert!(
        r_on.transactions < r_off.transactions,
        "batched {} vs unbatched {}",
        r_on.transactions,
        r_off.transactions
    );
    assert!(r_on.ticks <= r_off.ticks, "batching must not slow the target down");
}

#[test]
fn baud_rate_changes_target_time_not_results() {
    let Some(elf) = guest("hello") else { return };
    let mut slow = fase_cfg(1);
    slow.mode = Mode::Fase {
        transport: TransportSpec::uart(115_200),
        hfutex: true,
        latency: HostLatency::zero(),
    };
    let fast = fase_cfg(1);
    let r_slow = run(slow, &elf, &[], &[]);
    let r_fast = run(fast, &elf, &[], &[]);
    assert_eq!(r_slow.exit_code, 42);
    assert_eq!(r_fast.exit_code, 42);
    assert!(r_slow.ticks > r_fast.ticks, "slower channel => more target time");
}
