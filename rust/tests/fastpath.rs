//! LSU fast-path equivalence tests (DESIGN.md §LSU fast path).
//!
//! The softmmu-style fast path must be *state-invariant*: every counter,
//! cycle count, and byte of memory evolves exactly as on the slow path —
//! the only difference is host time. These tests drive both modes over
//! identical access scripts at the MemSys level, check the bypass edges
//! (line crossing, out-of-DRAM, shootdowns), prove a fast store still
//! honors the SMC write-generation contract end-to-end on the block
//! engine, and pin the whole thing down with a byte-identical 12-scenario
//! sweep.

use fase::mem::mmu::{Satp, PTE_A, PTE_D, PTE_R, PTE_U, PTE_V, PTE_W, PTE_X};
use fase::mem::{LsuMode, MemEvents, MemSys};
use fase::rv64::decode::encode;
use fase::rv64::hart::PrivLevel;
use fase::rv64::inst::Width;
use fase::soc::machine::DRAM_BASE;
use fase::soc::{Machine, MachineConfig};
use fase::sweep::{run_sweep, Arm, SweepSpec, SynthKind, WorkloadSpec};

const VA: u64 = 0x4000_0000;
const RW: u64 = PTE_V | PTE_R | PTE_W | PTE_U | PTE_A | PTE_D;
const RO: u64 = PTE_V | PTE_R | PTE_U | PTE_A;
const RWX: u64 = PTE_V | PTE_R | PTE_W | PTE_X | PTE_U | PTE_A | PTE_D;

/// Root of the mmu-test-style 3-level SV39 table: the level-2 and level-1
/// tables live in the two pages after `root`, so every mapping must share
/// the root and mid-level indexes of `VA` (one 2 MiB region — plenty).
const ROOT: u64 = DRAM_BASE + 0x10_0000;

fn map_page(ms: &mut MemSys, va: u64, pa: u64, flags: u64) {
    let l2 = ROOT + 0x1000;
    let l1 = ROOT + 0x2000;
    ms.phys.write_u64(ROOT + ((va >> 30) & 0x1ff) * 8, ((l2 >> 12) << 10) | PTE_V);
    ms.phys.write_u64(l2 + ((va >> 21) & 0x1ff) * 8, ((l1 >> 12) << 10) | PTE_V);
    ms.phys.write_u64(l1 + ((va >> 12) & 0x1ff) * 8, ((pa >> 12) << 10) | flags);
}

fn satp() -> Satp {
    Satp::make(8, 1, ROOT >> 12)
}

fn sys(mode: LsuMode, n_harts: usize) -> MemSys {
    let mut ms = MemSys::new(n_harts, DRAM_BASE, 8 << 20);
    ms.set_lsu(mode);
    map_page(&mut ms, VA, DRAM_BASE + 0x20_0000, RW);
    map_page(&mut ms, VA + 0x1000, DRAM_BASE + 0x20_1000, RO);
    map_page(&mut ms, VA + 0x2000, DRAM_BASE + 0x20_2000, RWX);
    ms
}

fn events(e: &MemEvents) -> (u64, u64, u64, u64, u64, u64) {
    (e.l1i_miss, e.l1d_miss, e.l2_miss, e.tlb_miss, e.ptw_accesses, e.coherence_inval)
}

/// One scripted access sequence covering the fast-hit regime (repeat
/// same-line traffic), the promote-on-reuse fills, read-only pages,
/// line-crossing accesses, instruction fetches, cross-hart coherence and
/// LR/SC reservations. Returns every observable: per-op values and
/// cycles, then the final counter state.
fn drive(ms: &mut MemSys) -> Vec<u64> {
    let s = satp();
    let mut t: Vec<u64> = Vec::new();
    let ld = |ms: &mut MemSys, h: usize, va: u64, w: Width, t: &mut Vec<u64>| {
        let (v, c) = ms.vload(h, s, true, va, w).unwrap();
        t.push(v);
        t.push(c);
    };
    let st = |ms: &mut MemSys, h: usize, va: u64, w: Width, v: u64, t: &mut Vec<u64>| {
        t.push(ms.vstore(h, s, true, va, w, v).unwrap());
    };
    // Hart 0 warms a line, then re-hits it: walk, TLB-hit fill, fast hits.
    st(ms, 0, VA + 8, Width::D, 0x1111, &mut t);
    st(ms, 0, VA + 16, Width::D, 0x2222, &mut t);
    st(ms, 0, VA + 24, Width::D, 0x3333, &mut t);
    ld(ms, 0, VA + 8, Width::D, &mut t);
    ld(ms, 0, VA + 16, Width::W, &mut t);
    // Misaligned but line-contained, then a line-crossing store (bypass,
    // charged as two line accesses in both modes).
    ld(ms, 0, VA + 18, Width::W, &mut t);
    st(ms, 0, VA + 60, Width::D, 0x4444, &mut t);
    st(ms, 0, VA + 60, Width::D, 0x5555, &mut t);
    // Read-only page: loads fill the read view, re-hit the same line.
    ld(ms, 1, VA + 0x1000, Width::D, &mut t);
    ld(ms, 1, VA + 0x1008, Width::D, &mut t);
    ld(ms, 1, VA + 0x1008, Width::D, &mut t);
    // A store to the RO page must fault identically in both modes.
    t.push(ms.vstore(1, s, true, VA + 0x1010, Width::D, 1).is_err() as u64);
    // Cross-hart: hart 1 reads hart 0's hot line (pulls a copy, drops
    // hart 0's exclusivity), hart 0 stores again (coherence scan), then
    // re-enters the fast regime.
    ld(ms, 1, VA + 8, Width::D, &mut t);
    st(ms, 0, VA + 8, Width::D, 0x6666, &mut t);
    st(ms, 0, VA + 8, Width::D, 0x7777, &mut t);
    // LR/SC: hart 1 reserves the line, hart 0's store must kill it.
    let pa = DRAM_BASE + 0x20_0000;
    ms.set_reservation(1, pa);
    st(ms, 0, VA + 32, Width::D, 0x8888, &mut t);
    t.push(ms.check_reservation(1, pa) as u64);
    // Instruction side: translate + timing, re-hitting lines and pcs.
    for va in [VA + 0x2000, VA + 0x2004, VA + 0x2004, VA + 0x2040, VA + 0x2008] {
        let (pa, c) = ms.ifetch_translate(0, s, true, va).unwrap();
        t.push(pa);
        t.push(c);
        t.push(ms.ifetch_timing(0, pa));
    }
    // Shootdown edge: flush hart 0, then rebuild the fast state.
    ms.flush_tlb(0);
    st(ms, 0, VA + 8, Width::D, 0x9999, &mut t);
    st(ms, 0, VA + 8, Width::D, 0xaaaa, &mut t);
    // Final observables: counters and a physical readback.
    for h in 0..ms.n_harts() {
        let (a, b, c, d, e, f) = events(&ms.evt[h]);
        t.extend([a, b, c, d, e, f]);
        t.push(ms.tlbs[h].hits);
        t.push(ms.tlbs[h].misses);
    }
    for off in [8u64, 16, 24, 32, 56, 60] {
        t.push(ms.phys.read_u64(DRAM_BASE + 0x20_0000 + off).unwrap());
    }
    t.push(ms.page_gen((DRAM_BASE + 0x20_0000) >> 12) as u64);
    t
}

#[test]
fn fast_and_slow_traces_are_identical() {
    let mut slow = sys(LsuMode::Slow, 2);
    let mut fast = sys(LsuMode::Fast, 2);
    let ts = drive(&mut slow);
    let tf = drive(&mut fast);
    assert_eq!(ts, tf, "fast path changed an architectural observable");
    assert_eq!(slow.fastpath_stats().hits, 0, "slow mode must never take the fast path");
    let st = fast.fastpath_stats();
    assert!(st.hits > 0, "script never exercised the fast path: {st:?}");
    assert!(st.fills > 0, "TLB-hit accesses must fill the views: {st:?}");
    assert!(st.epoch_flushes >= 1, "flush_tlb must bump the epoch: {st:?}");
}

#[test]
fn crossing_and_out_of_dram_accesses_bypass_the_fast_path() {
    let mut ms = sys(LsuMode::Fast, 1);
    // Line-crossing stores: even repeated, they must never fast-hit.
    for v in 0..4 {
        ms.vstore(0, satp(), true, VA + 60, Width::D, v).unwrap();
    }
    assert_eq!(ms.fastpath_stats().hits, 0, "crossing stores must stay on the slow path");
    // Same line, contained: third access onward replays.
    for v in 0..4 {
        ms.vstore(0, satp(), true, VA + 8, Width::D, v).unwrap();
    }
    assert!(ms.fastpath_stats().hits >= 2, "contained same-line stores must fast-hit");
    // A page mapped below DRAM (device space) is rejected by the check
    // and faults identically on the slow path.
    map_page(&mut ms, VA + 0x3000, 0x1000, RW);
    assert!(ms.vload(0, satp(), true, VA + 0x3000, Width::D).is_err());
    assert!(ms.vload(0, satp(), true, VA + 0x3000, Width::D).is_err());
}

#[test]
fn sfence_flush_prevents_stale_fast_translations() {
    let mut ms = sys(LsuMode::Fast, 1);
    let s = satp();
    // Enter the fast regime on VA -> pa1.
    for v in 0..3 {
        ms.vstore(0, s, true, VA + 8, Width::D, v).unwrap();
    }
    let hits0 = ms.fastpath_stats().hits;
    assert!(hits0 >= 1);
    // Remap VA to a different physical page and sfence. The next store
    // must walk the new table and land in the new page.
    let pa2 = DRAM_BASE + 0x30_0000;
    map_page(&mut ms, VA, pa2, RW);
    ms.flush_tlb(0);
    ms.vstore(0, s, true, VA + 8, Width::D, 0xfeed).unwrap();
    assert_eq!(ms.phys.read_u64(pa2 + 8), Some(0xfeed), "store must follow the remap");
    assert_eq!(
        ms.phys.read_u64(DRAM_BASE + 0x20_0000 + 8),
        Some(2),
        "old page keeps its pre-remap value"
    );
}

const ECALL: u32 = 0x0000_0073;

/// jal rd, off — pc-relative byte offset.
fn jal(rd: u8, off: i64) -> u32 {
    let v = off as u32;
    0x6f | ((rd as u32) << 7)
        | (((v >> 20) & 1) << 31)
        | (((v >> 1) & 0x3ff) << 21)
        | (((v >> 11) & 1) << 20)
        | (((v >> 12) & 0xff) << 12)
}

/// jalr rd, off(rs1)
fn jalr(rd: u8, rs1: u8, off: i32) -> u32 {
    ((off as u32 & 0xfff) << 20) | ((rs1 as u32) << 15) | ((rd as u32) << 7) | 0x67
}

fn write_prog(m: &mut Machine, at: u64, words: &[u32]) {
    for (i, w) in words.iter().enumerate() {
        m.ms.phys.write_n(at + 4 * i as u64, 4, *w as u64);
    }
}

/// Paged self-modifying code with *no* fence.i: user code patches a
/// subroutine through a writable alias of its physical page, with the
/// patching store arranged to take the LSU fast path (same line, warmed
/// write view). The fast store must still bump the page's write
/// generation, so the block engine's gen revalidation evicts the stale
/// decode and the second call runs the rewritten code.
fn run_paged_smc(lsu: LsuMode) -> ([u64; 32], u64, u64) {
    let mut m = Machine::new(MachineConfig {
        n_harts: 1,
        dram_size: 16 << 20,
        lsu,
        ..Default::default()
    });
    let root = DRAM_BASE + 0x10_0000;
    let pa_main = DRAM_BASE + 0x20_0000;
    let pa_tgt = DRAM_BASE + 0x20_1000;
    let va_main = VA;
    let va_tgt = VA + 0x2000;
    let va_data = VA + 0x4000; // writable alias of pa_tgt
    let xf = PTE_V | PTE_R | PTE_X | PTE_U | PTE_A;
    let l2 = root + 0x1000;
    let l1 = root + 0x2000;
    let map = |m: &mut Machine, va: u64, pa: u64, flags: u64| {
        m.ms.phys.write_u64(root + ((va >> 30) & 0x1ff) * 8, ((l2 >> 12) << 10) | PTE_V);
        m.ms.phys.write_u64(l2 + ((va >> 21) & 0x1ff) * 8, ((l1 >> 12) << 10) | PTE_V);
        m.ms.phys.write_u64(l1 + ((va >> 12) & 0x1ff) * 8, ((pa >> 12) << 10) | flags);
    };
    map(&mut m, va_main, pa_main, xf);
    map(&mut m, va_tgt, pa_tgt, xf);
    map(&mut m, va_data, pa_tgt, RW);
    write_prog(&mut m, pa_main, &[
        jal(1, 0x2000),        // call 1: t1 += 1 (block gets cached)
        encode::sd(9, 8, 8),   // warm store: TLB walk, no fill
        encode::sd(9, 8, 8),   // warm store: TLB hit, fills the write view
        encode::sd(18, 8, 0),  // PATCH through the fast path (same line)
        jal(1, 0x1ff0),        // call 2: must run the rewritten code
        ECALL,
    ]);
    write_prog(&mut m, pa_tgt, &[encode::addi(6, 6, 1), jalr(0, 1, 0)]);
    m.harts[0].regs[8] = va_data;
    m.harts[0].regs[9] = 0x5a5a_5a5a; // warm-store filler (bytes 8..16, never executed)
    m.harts[0].regs[18] = ((jalr(0, 1, 0) as u64) << 32) | encode::addi(6, 6, 100) as u64;
    m.harts[0].csrs.satp = Satp::make(8, 1, root >> 12).0;
    m.harts[0].prv = PrivLevel::U;
    m.harts[0].pc = va_main;
    m.harts[0].stop_fetch = false;
    assert!(m.run_until_exception(10_000_000), "program must reach its ecall");
    assert!(m.pop_exception().is_some());
    assert_eq!(m.harts[0].csrs.mcause, 8, "user ecall expected");
    if lsu == LsuMode::Fast {
        assert!(m.lsu_stats().hits > 0, "patch script must exercise the fast path");
        let s = m.engine_stats();
        assert!(s.evicted >= 1, "gen bump must evict the stale block: {s:?}");
    } else {
        assert_eq!(m.lsu_stats().hits, 0);
    }
    let h = &m.harts[0];
    (h.regs, h.time, h.instret)
}

#[test]
fn fast_store_smc_evicts_blocks_without_fence_i() {
    let slow = run_paged_smc(LsuMode::Slow);
    let fast = run_paged_smc(LsuMode::Fast);
    assert_eq!(fast.0[6], 101, "first call adds 1, patched call adds 100");
    assert_eq!(slow, fast, "LSU modes diverged in registers, time, or instret");
}

/// Run the 12-scenario matrix (storm/memtouch/stride x fase-loopback/
/// fullsys x 1,2 harts) under one LSU mode via the label-invisible
/// override and return the pretty-printed report plus retired counts.
fn lockstep_sweep(lsu: LsuMode) -> (String, Vec<u64>) {
    let mut spec = SweepSpec::new("lsu-lockstep");
    spec.seed = 0x5EED;
    spec.dram_size = 64 << 20;
    spec.max_target_seconds = 30.0;
    spec.workloads = vec![
        WorkloadSpec::synth(SynthKind::Storm { calls: 24 }),
        WorkloadSpec::synth(SynthKind::MemTouch { pages: 16 }),
        WorkloadSpec::synth(SynthKind::Stride { pages: 16, stride: 8 }),
    ];
    spec.arms = vec![
        Arm::Fase {
            transport: fase::fase::transport::TransportSpec::Loopback,
            hfutex: true,
            ideal_latency: false,
        },
        Arm::FullSys,
    ];
    spec.harts = vec![1, 2];
    spec.lsu_override = Some(lsu);
    let out = run_sweep(&spec, 2, None, false);
    assert!(out.errors().is_empty(), "sweep errors under {lsu}: {:?}", out.errors());
    let retired = out.outcomes.iter().map(|o| o.result.instret).collect();
    (out.to_json().to_string_pretty(), retired)
}

#[test]
fn lsu_modes_produce_byte_identical_sweep_reports() {
    let (report_s, retired_s) = lockstep_sweep(LsuMode::Slow);
    let (report_f, retired_f) = lockstep_sweep(LsuMode::Fast);
    assert!(retired_s.iter().sum::<u64>() > 0, "workloads must retire instructions");
    assert_eq!(retired_s, retired_f, "retired counts must match per scenario");
    assert!(report_s == report_f, "sweep reports must be byte-identical across LSU modes");
}
