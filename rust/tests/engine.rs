//! Cross-engine equivalence tests (DESIGN.md §Engine).
//!
//! The interpreter and the decoded basic-block engine must be
//! architecturally *and* cycle-identical: same registers, same hart time,
//! same retired-instruction counts, byte-identical sweep reports. These
//! tests also pin down the invalidation rules — stores into cached code
//! plus `fence.i`, and `sfence.vma` across an ASID remap.

use fase::iface::CpuInterface;
use fase::mem::mmu::{Satp, PTE_A, PTE_R, PTE_U, PTE_V, PTE_X};
use fase::rv64::csr;
use fase::rv64::decode::encode;
use fase::rv64::hart::PrivLevel;
use fase::rv64::EngineKind;
use fase::soc::machine::DRAM_BASE;
use fase::soc::{Machine, MachineConfig};
use fase::sweep::{run_sweep, Arm, SweepSpec, SynthKind, WorkloadSpec};

const ECALL: u32 = 0x0000_0073;

/// jal rd, off — pc-relative byte offset (the controller's encoder set
/// only covers injected sequences, so tests encode jumps themselves).
fn jal(rd: u8, off: i64) -> u32 {
    let v = off as u32;
    0x6f | ((rd as u32) << 7)
        | (((v >> 20) & 1) << 31)
        | (((v >> 1) & 0x3ff) << 21)
        | (((v >> 11) & 1) << 20)
        | (((v >> 12) & 0xff) << 12)
}

/// jalr rd, off(rs1)
fn jalr(rd: u8, rs1: u8, off: i32) -> u32 {
    ((off as u32 & 0xfff) << 20) | ((rs1 as u32) << 15) | ((rd as u32) << 7) | 0x67
}

fn machine(kind: EngineKind) -> Machine {
    Machine::new(MachineConfig {
        n_harts: 1,
        dram_size: 8 << 20,
        engine: kind,
        ..Default::default()
    })
}

fn write_prog(m: &mut Machine, at: u64, words: &[u32]) {
    for (i, w) in words.iter().enumerate() {
        m.ms.phys.write_n(at + 4 * i as u64, 4, *w as u64);
    }
}

/// Self-modifying code: call a subroutine (caching its block), patch both
/// of its instruction words with one sd, fence.i, call it again. The
/// second call must execute the rewritten code, and both engines must end
/// in the identical architectural state at the identical hart time.
fn run_smc(kind: EngineKind) -> ([u64; 32], u64, u64) {
    let mut m = machine(kind);
    let main = DRAM_BASE + 0x1000;
    let target = main + 0x40;
    write_prog(&mut m, main, &[
        jal(1, 0x40),          // call target (block gets cached)
        encode::sd(9, 8, 0),   // patch target's two instruction words
        encode::fence_i(),
        jal(1, 0x34),          // call target again (0x40 - 0xc)
        encode::self_loop(),
    ]);
    write_prog(&mut m, target, &[encode::addi(6, 6, 1), jalr(0, 1, 0)]);
    m.harts[0].regs[8] = target;
    m.harts[0].regs[9] = ((jalr(0, 1, 0) as u64) << 32) | encode::addi(6, 6, 100) as u64;
    m.harts[0].pc = main;
    m.harts[0].stop_fetch = false;
    m.run_until(200_000);
    if kind == EngineKind::Block {
        let s = m.engine_stats();
        assert!(s.blocks_built >= 5, "five distinct blocks plus a rebuild: {s:?}");
        assert!(s.evicted >= 1, "the patched block must be evicted: {s:?}");
        assert!(s.block_hits >= 1, "the self-loop must hit the cache: {s:?}");
    }
    let h = &m.harts[0];
    (h.regs, h.time, h.instret)
}

#[test]
fn smc_store_plus_fence_i_executes_rewritten_code_on_both_engines() {
    let interp = run_smc(EngineKind::Interp);
    let block = run_smc(EngineKind::Block);
    assert_eq!(interp.0[6], 101, "first call adds 1, patched call adds 100");
    assert_eq!(interp, block, "engines diverged in registers, time, or instret");
}

const VA: u64 = 0x4000_0000;

/// Build the mmu-test-style 3-level SV39 table mapping one 4K page.
fn map_page(m: &mut Machine, root: u64, va: u64, pa: u64, flags: u64) {
    let l2 = root + 0x1000;
    let l1 = root + 0x2000;
    m.ms.phys.write_u64(root + ((va >> 30) & 0x1ff) * 8, ((l2 >> 12) << 10) | PTE_V);
    m.ms.phys.write_u64(l2 + ((va >> 21) & 0x1ff) * 8, ((l1 >> 12) << 10) | PTE_V);
    m.ms.phys.write_u64(l1 + ((va >> 12) & 0x1ff) * 8, ((pa >> 12) << 10) | flags);
}

/// Paged SMC via the page tables: run user code at VA, remap VA to a
/// different physical page under a new ASID (then again under the same
/// ASID) with `sfence.vma` executed through the inject port, and check
/// that every pass fetches through the *current* translation.
fn run_remap(kind: EngineKind) -> ([u64; 32], u64, u64) {
    let mut m = machine(kind);
    let root = DRAM_BASE + 0x10_0000;
    let pa1 = DRAM_BASE + 0x20_0000;
    let pa2 = DRAM_BASE + 0x21_0000;
    let flags = PTE_V | PTE_R | PTE_X | PTE_U | PTE_A;
    write_prog(&mut m, pa1, &[encode::addi(5, 5, 1), ECALL]);
    write_prog(&mut m, pa2, &[encode::addi(5, 5, 2), ECALL]);
    map_page(&mut m, root, VA, pa1, flags);
    m.harts[0].csrs.satp = Satp::make(8, 1, root >> 12).0;
    m.harts[0].prv = PrivLevel::U;
    m.harts[0].pc = VA;
    m.harts[0].stop_fetch = false;

    assert!(m.run_until_exception(10_000_000));
    assert!(m.pop_exception().is_some());
    assert_eq!(m.harts[0].csrs.mcause, 8, "user ecall expected");
    assert_eq!(m.harts[0].regs[5], 1);

    // Remap VA -> pa2 and switch to ASID 2; flush via injected sfence.vma.
    let leaf = root + 0x2000 + ((VA >> 12) & 0x1ff) * 8;
    m.ms.phys.write_u64(leaf, ((pa2 >> 12) << 10) | flags);
    m.reg_write(0, 1, Satp::make(8, 2, root >> 12).0);
    m.inject(0, encode::csrrw(0, csr::SATP, 1));
    m.inject(0, encode::sfence_vma());
    m.reg_write(0, 1, VA);
    m.inject(0, encode::csrrw(0, csr::MEPC, 1));
    m.inject(0, encode::mret());
    m.set_stop_fetch(0, false);
    assert!(m.run_until_exception(20_000_000));
    assert!(m.pop_exception().is_some());
    assert_eq!(m.harts[0].regs[5], 3, "ASID remap must fetch the new page");

    // Same-ASID PTE rewrite back to pa1 + sfence.vma.
    m.ms.phys.write_u64(leaf, ((pa1 >> 12) << 10) | flags);
    m.inject(0, encode::sfence_vma());
    m.reg_write(0, 1, VA);
    m.inject(0, encode::csrrw(0, csr::MEPC, 1));
    m.inject(0, encode::mret());
    m.set_stop_fetch(0, false);
    assert!(m.run_until_exception(30_000_000));
    assert!(m.pop_exception().is_some());
    assert_eq!(m.harts[0].regs[5], 4, "sfence.vma must drop the stale translation");

    let h = &m.harts[0];
    (h.regs, h.time, h.instret)
}

#[test]
fn sfence_vma_asid_remap_agrees_across_engines() {
    let interp = run_remap(EngineKind::Interp);
    let block = run_remap(EngineKind::Block);
    assert_eq!(interp, block, "engines diverged in registers, time, or instret");
}

/// Run the lockstep matrix (spin/storm/memtouch x fase-loopback/fullsys x
/// 1,2 harts) on one engine via the label-invisible override and return
/// the pretty-printed report plus per-scenario retired counts.
fn lockstep_sweep(kind: EngineKind) -> (String, Vec<u64>) {
    let mut spec = SweepSpec::new("lockstep");
    spec.seed = 0x5EED;
    spec.dram_size = 64 << 20;
    spec.max_target_seconds = 30.0;
    spec.workloads = vec![
        WorkloadSpec::synth(SynthKind::Spin { iters: 300 }),
        WorkloadSpec::synth(SynthKind::Storm { calls: 24 }),
        WorkloadSpec::synth(SynthKind::MemTouch { pages: 16 }),
    ];
    spec.arms = vec![
        Arm::Fase {
            transport: fase::fase::transport::TransportSpec::Loopback,
            hfutex: true,
            ideal_latency: false,
        },
        Arm::FullSys,
    ];
    spec.harts = vec![1, 2];
    spec.engine_override = Some(kind);
    let out = run_sweep(&spec, 2, None, false);
    assert!(out.errors().is_empty(), "sweep errors on {kind}: {:?}", out.errors());
    let retired = out.outcomes.iter().map(|o| o.result.instret).collect();
    (out.to_json().to_string_pretty(), retired)
}

#[test]
fn engines_produce_byte_identical_sweep_reports() {
    let (report_i, retired_i) = lockstep_sweep(EngineKind::Interp);
    let (report_b, retired_b) = lockstep_sweep(EngineKind::Block);
    assert!(retired_i.iter().sum::<u64>() > 0, "workloads must retire instructions");
    assert_eq!(retired_i, retired_b, "retired counts must match per scenario");
    assert!(report_i == report_b, "sweep reports must be byte-identical across engines");
}
